//! Performance experiments: Figure 6 (theoretical speedup) and Table 14
//! (runtime decomposition / overhead), plus the measured decomposition of
//! *our* stack feeding back into the same cost model.

use super::{save_json, ExpCtx};
use crate::backend::tensor;
use crate::cli::Args;
use crate::coordinator::StepExecutor;
use crate::metrics::Table;
use crate::perfmodel::{Decomposition, SpeedupModel, PAPER_TABLE14};
use crate::util::error::{err, Result};
use crate::util::json::{self, Json};

/// Fig 6: theoretical speedup at 90% quantization via the paper's linear
/// cost model — exact from the paper's own Table-14 decomposition, plus
/// the same model over our measured decomposition.
pub fn fig6(args: &Args) -> Result<()> {
    let p = args.f64_or("fraction", 0.9)?;
    let s = args.f64_or("speedup-factor", 4.0)?;
    // Analysis cost amortized per iteration: (n_layers+1)·R probe steps
    // every n_interval epochs — with n_sample=1 probes the paper treats
    // it as ~1-2% of an iteration; expose as a flag.
    let analysis_frac = args.f64_or("analysis-frac", 0.02)?;

    let mut table = Table::new(&["config", "overhead %", "T_ours/T_base", "speedup"]);
    let mut rows = Vec::new();
    for &(name, total, _good, overhead) in PAPER_TABLE14 {
        let m = SpeedupModel::from_table14(total, overhead, analysis_frac * total, s);
        let sp = m.speedup(p);
        table.row(vec![
            name.into(),
            format!("{:.2}", 100.0 * overhead / total),
            format!("{:.3}", 1.0 / sp),
            format!("{sp:.2}x"),
        ]);
        rows.push(json::obj(vec![
            ("config", json::s(name)),
            ("speedup", json::num(sp)),
        ]));
    }
    println!("Fig 6 — theoretical speedup at p = {p} with {s}x low-precision ops");
    table.print();
    println!("paper band: 1.75x – 2.21x at p = 0.9 (matches the shape above)");
    save_json("fig6", Json::Arr(rows))
}

/// Measure our own runtime decomposition (Table 14 analogue): time the
/// executor's fused step (fwd+bwd+clip), the noise draw, the optimizer
/// update, and batch assembly, then feed the same Fig-6 model.
pub fn tab14(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let exec = ctx.exec.as_ref();
    let b = exec.physical_batch();
    let batches = crate::data::eval_batches(&ctx.train_ds, b);
    let batch = &batches[0];
    let mask = vec![1f32; exec.n_quant_layers()];
    let reps = args.usize_or("reps", 10)?;

    // Step time (forward + backward + per-sample clip, inside the
    // executor — XLA for pjrt, the pure-Rust engine for native).
    let w = exec.initial_weights();
    exec.train_step(&w, &batch.x, &batch.y, &batch.mask, &mask, 0.0)?; // warmup
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        exec.train_step(&w, &batch.x, &batch.y, &batch.mask, &mask, i as f32)?;
    }
    let t_graph = t0.elapsed().as_secs_f64() / reps as f64;

    // Noise generation over all params (the DP mechanism).
    let sizes = exec.param_sizes();
    let mut gaus = crate::util::gaussian::GaussianSampler::seed_from_u64(1);
    let mut bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0f32; n]).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for buf in bufs.iter_mut() {
            gaus.add_noise_f32(buf, 1.0);
        }
    }
    let t_noise = t0.elapsed().as_secs_f64() / reps as f64;

    // Optimizer scale + update (SGD arithmetic).
    let mut weights = exec.initial_weights();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for (wt, g) in weights.iter_mut().zip(&bufs) {
            for (wi, gi) in wt.iter_mut().zip(g) {
                *wi -= 0.5 * gi / 64.0;
            }
        }
    }
    let t_update = t0.elapsed().as_secs_f64() / reps as f64;

    // Batch assembly (data movement "other").
    let idx: Vec<usize> = (0..b.min(ctx.train_ds.len())).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let _ = crate::data::make_batches(&ctx.train_ds, &idx, b);
    }
    let t_other = t0.elapsed().as_secs_f64() / reps as f64;

    // The compiled graph fuses fwd/bwd/clip; split by the paper's typical
    // 1:2 fwd:bwd ratio with clip ~5% for reporting.
    let d = Decomposition {
        forward: t_graph * 0.32,
        backward: t_graph * 0.63,
        optimizer_clip: t_graph * 0.05,
        optimizer_noise: t_noise,
        optimizer_scale: t_update * 0.5,
        other_optimizer: t_update * 0.5,
        other: t_other,
    };
    let mut table = Table::new(&["stage", "ms/iter", "low-precision speedup?"]);
    for (name, v, good) in [
        ("forward", d.forward, true),
        ("backward", d.backward, true),
        ("optimizer clip", d.optimizer_clip, true),
        ("optimizer noise", d.optimizer_noise, false),
        ("optimizer scale", d.optimizer_scale, true),
        ("other optimizer", d.other_optimizer, false),
        ("other (data)", d.other, false),
    ] {
        table.row(vec![
            name.into(),
            format!("{:.3}", v * 1e3),
            if good { "yes" } else { "no" }.into(),
        ]);
    }
    println!("Table 14 (ours) — measured decomposition per iteration (batch {b})");
    table.print();
    println!(
        "total {:.2} ms, overhead {:.2}% (paper overheads: 4.6–19.8%)",
        d.total() * 1e3,
        d.overhead_pct()
    );
    let m = SpeedupModel::from_decomposition(&d, 0.02 * d.total(), 4.0);
    println!(
        "cost-model speedup at p=0.9 on OUR decomposition: {:.2}x (paper: 1.75–2.21x)",
        m.speedup(0.9)
    );
    save_json(
        "tab14",
        json::obj(vec![
            ("graph_ms", json::num(t_graph * 1e3)),
            ("noise_ms", json::num(t_noise * 1e3)),
            ("update_ms", json::num(t_update * 1e3)),
            ("other_ms", json::num(t_other * 1e3)),
            ("overhead_pct", json::num(d.overhead_pct())),
            ("model_speedup_p09", json::num(m.speedup(0.9))),
        ]),
    )
}

/// Wire-format name of the bench snapshot (`"format"` field).
pub const BENCH_FORMAT: &str = "dpquant-bench";
/// Wire-format version this build emits and `--check` validates.
pub const BENCH_VERSION: u32 = 1;

/// Time `reps` calls of `f` (after one warmup call), in ns per call.
///
/// Floored at a millinanosecond so downstream ratios can never divide
/// by zero even on a clock-resolution fluke.
fn time_ns<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    (t0.elapsed().as_secs_f64() * 1e9 / reps as f64).max(1e-3)
}

/// Fill `buf` with deterministic pseudo-random values in [-0.5, 0.5).
fn fill_rand(rng: &mut crate::util::rng::Xoshiro256, buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = rng.next_f32() - 0.5;
    }
}

/// `a / b` with a finite-value guard: any non-finite or non-positive
/// input collapses to 0.0 (the `--check` validator rejects NaN/inf, so
/// the emitter must never produce them).
fn ratio(a: f64, b: f64) -> f64 {
    if a.is_finite() && b.is_finite() && a > 0.0 && b > 0.0 {
        a / b
    } else {
        0.0
    }
}

/// `dpquant bench` — the per-PR native performance snapshot.
///
/// Times the retained naive reference kernels against their blocked
/// rewrites (per-call ns + naive/blocked speedup), the quantizer
/// kernels (ns per element), and the full native `train_step`
/// (steps/sec for fp32 and each quantizer), then emits a
/// `dpquant-bench` v1 JSON blob (schema: DESIGN.md §13.4) to the
/// `--json PATH` file. With `--check FILE` it validates an existing
/// blob against the schema instead of measuring — CI runs this over
/// both a fresh quick emit and the committed `BENCH_native.json`.
/// `DPQUANT_BENCH_QUICK=1` caps iteration counts so the harness
/// smoke-tests in seconds (quick numbers are marked `"quick": true`
/// and are not comparable across machines).
///
/// Every measurement is mirrored into the global metrics registry as a
/// `bench.<group>.<name>` gauge, and the bench run itself executes
/// with kernel timing on — so `--metrics-out PATH` dumps a
/// `dpquant-metrics` v1 snapshot holding both the gauges and the live
/// `kernel.*_ns` histograms the timed kernels just fed.
pub fn bench(args: &Args) -> Result<()> {
    if let Some(path) = args.get("check") {
        return bench_check(&path, args.has_flag("allow-provisional"));
    }
    crate::obs::set_kernel_timing(true);
    let quick = std::env::var_os("DPQUANT_BENCH_QUICK").is_some();
    let reps = {
        let r = args.usize_or("reps", 40)?.max(1);
        if quick {
            r.min(2)
        } else {
            r
        }
    };
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(42);
    let mut kernels: Vec<(String, f64)> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    // --- GEMM: naive row-update loop vs MC/KC/NC-blocked ------------------
    for &(m, k, n) in &[(96usize, 256usize, 96usize), (256, 256, 256)] {
        let mut a = vec![0f32; m * k];
        let mut bm = vec![0f32; k * n];
        fill_rand(&mut rng, &mut a);
        fill_rand(&mut rng, &mut bm);
        let mut out = vec![0f32; m * n];
        let naive = time_ns(reps, || tensor::matmul(&a, &bm, m, k, n, &mut out));
        let blocked = time_ns(reps, || tensor::matmul_blocked(&a, &bm, m, k, n, &mut out));
        let tag = format!("matmul_{m}x{k}x{n}");
        kernels.push((format!("{tag}_naive"), naive));
        kernels.push((format!("{tag}_blocked"), blocked));
        speedups.push((tag, ratio(naive, blocked)));
    }

    // --- conv3x3 fwd/bwd at the miniconvnet layer-1 shape ------------------
    {
        let (h, wd, cin, cout) = (16usize, 16usize, 8usize, 16usize);
        let mut w = vec![0f32; cout * cin * 9];
        let mut bias = vec![0f32; cout];
        let mut a = vec![0f32; h * wd * cin];
        let mut dy = vec![0f32; h * wd * cout];
        fill_rand(&mut rng, &mut w);
        fill_rand(&mut rng, &mut bias);
        fill_rand(&mut rng, &mut a);
        fill_rand(&mut rng, &mut dy);
        let mut out = vec![0f32; h * wd * cout];
        let tag = format!("conv3x3_{h}x{wd}x{cin}x{cout}");
        let naive = time_ns(reps, || {
            tensor::conv3x3_forward_ref(&w, &bias, &a, &mut out, h, wd, cin, cout)
        });
        let blocked = time_ns(reps, || {
            tensor::conv3x3_forward(&w, &bias, &a, &mut out, h, wd, cin, cout)
        });
        kernels.push((format!("{tag}_forward_naive"), naive));
        kernels.push((format!("{tag}_forward_blocked"), blocked));
        speedups.push(("conv3x3_forward".into(), ratio(naive, blocked)));

        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; cout];
        let mut da = vec![0f32; a.len()];
        let naive = time_ns(reps, || {
            gw.fill(0.0);
            gb.fill(0.0);
            tensor::conv3x3_backward_ref(
                &w, &a, &dy, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout,
            );
        });
        let blocked = time_ns(reps, || {
            gw.fill(0.0);
            gb.fill(0.0);
            tensor::conv3x3_backward(&w, &a, &dy, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout);
        });
        kernels.push((format!("{tag}_backward_naive"), naive));
        kernels.push((format!("{tag}_backward_blocked"), blocked));
        speedups.push(("conv3x3_backward".into(), ratio(naive, blocked)));
    }

    // --- dense matvec (the classifier-head shape) --------------------------
    {
        let (input, output) = (1024usize, 96usize);
        let mut w = vec![0f32; output * input];
        let mut bias = vec![0f32; output];
        let mut a = vec![0f32; input];
        fill_rand(&mut rng, &mut w);
        fill_rand(&mut rng, &mut bias);
        fill_rand(&mut rng, &mut a);
        let mut out = vec![0f32; output];
        let tag = format!("dense_forward_{input}x{output}");
        let naive = time_ns(reps * 4, || {
            tensor::dense_forward_ref(&w, Some(&bias), &a, &mut out)
        });
        let blocked = time_ns(reps * 4, || tensor::dense_forward(&w, Some(&bias), &a, &mut out));
        kernels.push((format!("{tag}_naive"), naive));
        kernels.push((format!("{tag}_blocked"), blocked));
        speedups.push(("dense_forward".into(), ratio(naive, blocked)));
    }

    // --- Quantizer kernels (ns/elem over a 64k-element tensor) -------------
    {
        let mut g = crate::util::gaussian::GaussianSampler::seed_from_u64(9);
        let base: Vec<f32> = (0..65_536).map(|_| g.standard() as f32).collect();
        for name in ["luq4", "uniform4", "fp8"] {
            let q = crate::quant::by_name(name)
                .ok_or_else(|| err!("bench: unknown quantizer {name}"))?;
            let mut buf = base.clone();
            let per_call = time_ns(reps, || {
                buf.copy_from_slice(&base);
                q.quantize(&mut buf, &mut rng);
            });
            kernels.push((format!("quant_{name}_per_elem"), per_call / base.len() as f64));
        }
    }

    // --- Native train_step: steps/sec, fp32 baseline vs each quantizer ----
    let bsz = 32usize;
    let step_reps = if quick { 2 } else { reps.clamp(5, 20) };
    let nds = crate::data::generate("gtsrb", bsz, 7)?;
    let batches = crate::data::eval_batches(&nds, bsz);
    let batch = &batches[0];
    let mk = |quantizer: &str| -> Result<crate::backend::NativeExecutor> {
        let cfg = crate::config::TrainConfig {
            model: "miniconvnet".into(),
            dataset: "gtsrb".into(),
            quantizer: quantizer.into(),
            physical_batch: bsz,
            ..crate::config::TrainConfig::default()
        };
        crate::backend::NativeExecutor::from_config(&cfg, nds.example_numel, nds.n_classes)
    };
    let time_steps = |exec: &crate::backend::NativeExecutor, mask: &[f32]| -> Result<f64> {
        let w = exec.initial_weights();
        exec.train_step(&w, &batch.x, &batch.y, &batch.mask, mask, 0.0)?;
        let t0 = std::time::Instant::now();
        for i in 0..step_reps {
            exec.train_step(&w, &batch.x, &batch.y, &batch.mask, mask, i as f32 + 1.0)?;
        }
        Ok(step_reps as f64 / t0.elapsed().as_secs_f64().max(1e-12))
    };
    let mut steps: Vec<(String, f64)> = Vec::new();
    let fp_exec = mk("luq4")?;
    let nl = fp_exec.n_quant_layers();
    let fp32_sps = time_steps(&fp_exec, &vec![0f32; nl])?;
    steps.push(("fp32".into(), fp32_sps));
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for name in ["luq4", "uniform4", "fp8"] {
        let exec = mk(name)?;
        let sps = time_steps(&exec, &vec![1f32; exec.n_quant_layers()])?;
        // >1.0 means the quantized step is slower than fp32 (scalar
        // quantizer overhead); a low-precision ALU would flip this.
        ratios.push((name.into(), ratio(fp32_sps, sps)));
        steps.push((name.into(), sps));
    }

    // --- Report ------------------------------------------------------------
    let mut table = Table::new(&["kernel", "ns/call"]);
    for (k, v) in &kernels {
        table.row(vec![k.clone(), format!("{v:.1}")]);
    }
    println!("dpquant bench — native kernel snapshot (reps {reps}, quick {quick})");
    table.print();
    let mut table = Table::new(&["kernel", "naive/blocked speedup"]);
    for (k, v) in &speedups {
        table.row(vec![k.clone(), format!("{v:.2}x")]);
    }
    table.print();
    let mut table = Table::new(&["config", "steps/sec", "fp32/quantized"]);
    for (k, v) in &steps {
        let r = ratios.iter().find(|(n, _)| n == k).map(|(_, r)| format!("{r:.2}"));
        table.row(vec![k.clone(), format!("{v:.2}"), r.unwrap_or_else(|| "-".into())]);
    }
    table.print();

    let to_obj = |pairs: &[(String, f64)]| {
        Json::Obj(
            pairs
                .iter()
                .map(|(k, v)| (k.clone(), json::num(if v.is_finite() { *v } else { 0.0 })))
                .collect(),
        )
    };
    let doc = json::obj(vec![
        ("format", json::s(BENCH_FORMAT)),
        ("version", json::num(BENCH_VERSION as f64)),
        ("quick", Json::Bool(quick)),
        ("provisional", Json::Bool(false)),
        ("reps", json::num(reps as f64)),
        ("batch", json::num(bsz as f64)),
        ("kernels_ns", to_obj(&kernels)),
        ("blocked_speedup", to_obj(&speedups)),
        ("steps_per_sec", to_obj(&steps)),
        ("fp32_vs_quantized", to_obj(&ratios)),
    ]);
    // Mirror the snapshot into the global registry so a single
    // `--metrics-out` file carries the bench numbers alongside the
    // kernel histograms the timed calls above just recorded.
    let reg = crate::obs::global();
    for (group, pairs) in [
        ("kernels_ns", &kernels),
        ("blocked_speedup", &speedups),
        ("steps_per_sec", &steps),
        ("fp32_vs_quantized", &ratios),
    ] {
        for (k, v) in pairs {
            reg.gauge(&format!("bench.{group}.{k}")).set(*v);
        }
    }
    if let Some(path) = args.get("json") {
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("[bench json -> {path}]");
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(&path, format!("{}\n", crate::obs::metrics_doc()))?;
        println!("[bench metrics -> {path}]");
    }
    Ok(())
}

/// Validate a `dpquant-bench` v1 blob: format/version pins, the
/// family's numeric groups present and non-empty, the per-group
/// required keys, and every number finite. Two families share the
/// format: `"native"` (kernel/step timings, the default when the
/// `family` field is absent — every pre-ledger blob) and `"serve"`
/// (loadgen latency percentiles + admission counts, see
/// [`crate::serve::loadgen`]). Used by the CI `bench-json` job
/// against fresh quick emits and the committed `BENCH_native.json` /
/// `BENCH_serve.json`. Blobs marked `"provisional": true` (placeholder
/// numbers, not measurements) are rejected unless `--allow-provisional`
/// is passed — committed snapshots must be real measurements.
fn bench_check(path: &str, allow_provisional: bool) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| err!("bench --check: cannot read {path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| err!("bench --check: {path}: invalid JSON: {e}"))?;
    let fmt = doc.get("format").and_then(Json::as_str).unwrap_or("");
    if fmt != BENCH_FORMAT {
        return Err(err!("bench --check: {path}: format {fmt:?} != {BENCH_FORMAT:?}"));
    }
    let ver = doc.get("version").and_then(Json::as_f64).unwrap_or(0.0);
    if ver != BENCH_VERSION as f64 {
        return Err(err!("bench --check: {path}: version {ver} != {BENCH_VERSION}"));
    }
    let provisional = doc
        .get("provisional")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    if provisional && !allow_provisional {
        return Err(err!(
            "bench --check: {path}: blob is marked provisional (placeholder numbers); \
             re-measure it or pass --allow-provisional"
        ));
    }
    let family = doc.get("family").and_then(Json::as_str).unwrap_or("native");
    let required: &[(&str, &[&str])] = match family {
        "native" => &[
            ("kernels_ns", &[]),
            (
                "blocked_speedup",
                &[
                    "matmul_96x256x96",
                    "matmul_256x256x256",
                    "conv3x3_forward",
                    "conv3x3_backward",
                    "dense_forward",
                ],
            ),
            ("steps_per_sec", &["fp32", "luq4", "uniform4", "fp8"]),
            ("fp32_vs_quantized", &["luq4", "uniform4", "fp8"]),
        ],
        "serve" => &[
            ("load", &["tenants", "jobs_per_tenant", "concurrency"]),
            ("counts", &["submitted", "accepted", "rejected_budget"]),
            ("submit_ms", &["p50", "p90", "p99"]),
            ("wait_ms", &["p50", "p90", "p99"]),
        ],
        other => {
            return Err(err!(
                "bench --check: {path}: unknown bench family {other:?} \
                 (this build knows \"native\" and \"serve\")"
            ))
        }
    };
    let mut n_values = 0usize;
    for &(group, keys) in required {
        let obj = doc
            .get(group)
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("bench --check: {path}: missing object {group:?}"))?;
        if obj.is_empty() {
            return Err(err!("bench --check: {path}: {group} is empty"));
        }
        for key in keys {
            if !obj.contains_key(*key) {
                return Err(err!("bench --check: {path}: {group} is missing key {key:?}"));
            }
        }
        for (k, v) in obj {
            let x = v
                .as_f64()
                .ok_or_else(|| err!("bench --check: {path}: {group}.{k} is not a number"))?;
            if !x.is_finite() {
                return Err(err!("bench --check: {path}: {group}.{k} = {x} is not finite"));
            }
            n_values += 1;
        }
    }
    println!(
        "[bench check ok] {path}: {BENCH_FORMAT} v{BENCH_VERSION} family {family}, \
         {n_values} finite metrics"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_native_blob(tag: &str, provisional: bool) -> String {
        let path = std::env::temp_dir()
            .join(format!("dpquant_bench_{tag}_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let doc = format!(
            "{{\"format\":\"{BENCH_FORMAT}\",\"version\":{BENCH_VERSION},\
             \"provisional\":{provisional},\"quick\":false,\
             \"kernels_ns\":{{\"matmul_96x256x96_blocked\":1200.5}},\
             \"blocked_speedup\":{{\"matmul_96x256x96\":3.0,\"matmul_256x256x256\":3.5,\
             \"conv3x3_forward\":2.0,\"conv3x3_backward\":2.2,\"dense_forward\":1.8}},\
             \"steps_per_sec\":{{\"fp32\":25.0,\"luq4\":20.0,\"uniform4\":21.0,\"fp8\":22.0}},\
             \"fp32_vs_quantized\":{{\"luq4\":1.25,\"uniform4\":1.19,\"fp8\":1.14}}}}\n"
        );
        std::fs::write(&path, doc).unwrap();
        path
    }

    #[test]
    fn check_accepts_a_measured_blob() {
        let path = write_native_blob("measured", false);
        bench_check(&path, false).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_rejects_provisional_unless_allowed() {
        let path = write_native_blob("prov", true);
        let e = bench_check(&path, false).unwrap_err().to_string();
        assert!(e.contains("provisional"), "{e}");
        bench_check(&path, true).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
