//! Figure experiments (Fig 1a/1b/1c, 3, 4, 5).

use super::{save_json, ExpCtx};
use crate::cli::Args;
use crate::metrics::{mean_std, Table};
use crate::privacy::{Mechanism, RdpAccountant};
use crate::util::error::Result;
use crate::util::json::{self, Json};

/// Fig 1a: accuracy loss vs #layers quantized, DP-SGD vs (near-)non-DP
/// SGD, error bars over random layer subsets.
///
/// "Non-DP" is emulated with σ→0 (the mechanism pipeline is identical;
/// clipping stays, which only helps the non-DP baseline — documented in
/// EXPERIMENTS.md).
pub fn fig1a(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let n = ctx.n_layers();
    let ks = [0usize, n / 4, n / 2, 3 * n / 4, n];

    let mut out_rows = Vec::new();
    let mut table = Table::new(&["mode", "k", "acc mean", "acc std", "acc drop"]);
    for (label, sigma) in [("non-DP", 1e-3), ("DP", 1.0)] {
        // Full-precision reference for this mode.
        let (fp_accs, _) = ctx.sweep("none", 0.0, |c| c.noise_multiplier = sigma)?;
        let (fp_mean, _) = mean_std(&fp_accs);
        for &k in &ks {
            let frac = k as f64 / n as f64;
            let (accs, _) = ctx.sweep("static_random", frac, |c| c.noise_multiplier = sigma)?;
            let (m, s) = mean_std(&accs);
            table.row(vec![
                label.into(),
                k.to_string(),
                format!("{m:.4}"),
                format!("{s:.4}"),
                format!("{:+.4}", m - fp_mean),
            ]);
            out_rows.push(json::obj(vec![
                ("mode", json::s(label)),
                ("k", json::num(k as f64)),
                ("acc_mean", json::num(m)),
                ("acc_std", json::num(s)),
                ("fp_ref", json::num(fp_mean)),
            ]));
        }
    }
    println!("Fig 1a — accuracy under quantization, DP vs non-DP (static random subsets)");
    table.print();
    println!("expect: DP drop and DP std both exceed non-DP at matching k (paper Fig 1a)");
    save_json("fig1a", Json::Arr(out_rows))
}

/// Fig 1b: distribution of clipped-gradient vs injected-noise magnitudes
/// — the paper's Eq. 2 (‖n‖∞ ≈ ‖ḡ‖₂ ≫ ‖ḡ‖∞; their measured gap ≈ 2⁵).
pub fn fig1b(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let mut cfg = ctx.base.clone();
    cfg.scheduler = "static_random".into();
    cfg.quant_fraction = 0.5;
    let res = ctx.run_cfg(&cfg, true)?;

    let ratios: Vec<f64> = res
        .trace
        .stats
        .iter()
        .filter(|s| s.grad_linf > 0.0 && s.noise_linf > 0.0)
        .map(|s| (s.noise_linf / s.grad_linf).log2())
        .collect();
    let l2_over_linf: Vec<f64> = res
        .trace
        .stats
        .iter()
        .filter(|s| s.grad_linf > 0.0)
        .map(|s| (s.grad_l2 / s.grad_linf).log2())
        .collect();
    let (rm, rs) = mean_std(&ratios);
    let (lm, _) = mean_std(&l2_over_linf);
    println!("Fig 1b — noise/gradient magnitude ratios over {} steps", ratios.len());
    println!("  log2(‖noise‖∞ / ‖ḡ‖∞): mean {rm:.2} ± {rs:.2}  (paper: ≈ 5, i.e. 2⁵ gap)");
    println!("  log2(‖ḡ‖₂ / ‖ḡ‖∞):     mean {lm:.2}  (high-dim norm gap driving Eq. 2)");
    save_json(
        "fig1b",
        json::obj(vec![
            ("log2_noise_over_grad_linf", json::arr_f64(&ratios)),
            ("log2_grad_l2_over_linf", json::arr_f64(&l2_over_linf)),
        ]),
    )
}

/// Fig 1c: distributions of raw (pre-clip) per-sample gradient norms
/// under SGD (σ≈0), noise-injection (σ=1, mid-clip), and full DP-SGD.
pub fn fig1c(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let mut series = Vec::new();
    let mut table = Table::new(&["mode", "raw-norm mean", "raw-norm max", "steps"]);
    for (label, sigma) in [("SGD", 1e-3), ("noise-injection", 0.5), ("DP-SGD", 1.0)] {
        let mut cfg = ctx.base.clone();
        cfg.scheduler = "none".into();
        cfg.noise_multiplier = sigma;
        let res = ctx.run_cfg(&cfg, true)?;
        let (m, _) = mean_std(&res.trace.raw_norm_mean);
        let mx = res
            .trace
            .raw_norm_max
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        table.row(vec![
            label.into(),
            format!("{m:.4}"),
            format!("{mx:.4}"),
            res.trace.raw_norm_mean.len().to_string(),
        ]);
        series.push(json::obj(vec![
            ("mode", json::s(label)),
            ("raw_norm_mean", json::arr_f64(&res.trace.raw_norm_mean)),
            ("raw_norm_max", json::arr_f64(&res.trace.raw_norm_max)),
        ]));
    }
    println!("Fig 1c — raw per-sample gradient norms (noise inflates later grads)");
    table.print();
    println!("expect: DP-SGD raw-norm mean ≳ SGD's (paper: ≈2×)");
    save_json("fig1c", Json::Arr(series))
}

/// Fig 3: privacy cost of analysis vs training — **exact** reproduction
/// (pure accountant math at the paper's own GTSRB configuration).
pub fn fig3(args: &Args) -> Result<()> {
    // Paper config: ResNet18/GTSRB, |D| = 26640, B = 1024, σ = 1.0,
    // 60 epochs, analysis every 2 epochs, n_sample = 1, σ_measure = 0.5.
    let d = args.f64_or("dataset-size", 26_640.0)?;
    let b = 1024.0;
    let q_train = b / d;
    let steps_per_epoch = (d / b).round() as u64;
    let epochs = 60u64;
    let q_meas = 1.0 / d; // n_sample = 1
    let sigma_meas = 0.5;
    let delta = 1e-5;

    let mut acc = RdpAccountant::new();
    let mut table = Table::new(&["epoch", "eps total", "eps train-only", "analysis frac"]);
    let mut epochs_j = Vec::new();
    for epoch in 0..epochs {
        if epoch % 2 == 0 {
            acc.step_analysis(q_meas, sigma_meas);
        }
        acc.step_training(q_train, 1.0, steps_per_epoch);
        if epoch % 6 == 5 || epoch == 0 {
            let (tot, _) = acc.epsilon(delta);
            let train_only = {
                let curve = acc.rdp_curve(Some(Mechanism::Training));
                crate::privacy::rdp_to_epsilon(acc.alphas(), &curve, delta).0
            };
            let frac = acc.analysis_fraction(delta);
            table.row(vec![
                (epoch + 1).to_string(),
                format!("{tot:.4}"),
                format!("{train_only:.4}"),
                format!("{frac:.4}"),
            ]);
            epochs_j.push(json::obj(vec![
                ("epoch", json::num((epoch + 1) as f64)),
                ("eps_total", json::num(tot)),
                ("eps_train", json::num(train_only)),
                ("analysis_fraction", json::num(frac)),
            ]));
        }
    }
    println!("Fig 3 — cumulative privacy: training + analysis (paper config, exact)");
    table.print();
    println!("expect: analysis fraction largest early, negligible (<~5%) by end of training");
    save_json("fig3", Json::Arr(epochs_j))
}

/// Fig 4: speed-accuracy Pareto — random static subsets vs DPQuant at
/// matched computational budgets.
pub fn fig4(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let n = ctx.n_layers();
    let fracs = [0.25, 0.5, 0.75, 0.9];
    let subsets = args.u64_or("subsets", 5)?;

    let mut rows = Vec::new();
    let mut table = Table::new(&["k/n", "random subsets (best/mean/worst)", "DPQuant"]);
    for &frac in &fracs {
        let mut rnd = Vec::new();
        for seed in 0..subsets {
            let mut cfg = ctx.base.clone();
            cfg.scheduler = "static_random".into();
            cfg.quant_fraction = frac;
            cfg.seed = 1000 + seed;
            rnd.push(ctx.run_cfg(&cfg, false)?.record.best_accuracy);
        }
        let best = rnd.iter().cloned().fold(0.0f64, f64::max);
        let worst = rnd.iter().cloned().fold(1.0f64, f64::min);
        let (mean, _) = mean_std(&rnd);

        let mut cfg = ctx.base.clone();
        cfg.scheduler = "dpquant".into();
        cfg.quant_fraction = frac;
        let ours = ctx.run_cfg(&cfg, false)?.record.best_accuracy;

        table.row(vec![
            format!("{:.2} ({}/{})", frac, crate::coordinator::budget_to_k(n, frac), n),
            format!("{best:.4} / {mean:.4} / {worst:.4}"),
            format!("{ours:.4}"),
        ]);
        rows.push(json::obj(vec![
            ("fraction", json::num(frac)),
            ("random", json::arr_f64(&rnd)),
            ("dpquant", json::num(ours)),
        ]));
    }
    println!("Fig 4 — Pareto: random subsets vs DPQuant (higher = better at same budget)");
    table.print();
    println!("expect: DPQuant near the best random subset (the empirical Pareto front)");
    save_json("fig4", Json::Arr(rows))
}

/// Fig 5: ablation — static baseline vs PLS alone vs PLS+LLP (DPQuant).
pub fn fig5(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let fracs = [0.5, 0.75, 0.9];
    let mut rows = Vec::new();
    let mut table = Table::new(&["k/n", "static (mean±std)", "PLS", "PLS+LLP (DPQuant)"]);
    for &frac in &fracs {
        let (static_accs, _) = ctx.sweep("static_random", frac, |_| {})?;
        let (sm, ss) = mean_std(&static_accs);
        let (pls_accs, _) = ctx.sweep("pls", frac, |_| {})?;
        let (pm, _) = mean_std(&pls_accs);
        let mut cfg = ctx.base.clone();
        cfg.scheduler = "dpquant".into();
        cfg.quant_fraction = frac;
        let ours = ctx.run_cfg(&cfg, false)?.record.best_accuracy;
        table.row(vec![
            format!("{frac:.2}"),
            format!("{sm:.4}±{ss:.4}"),
            format!("{pm:.4}"),
            format!("{ours:.4}"),
        ]);
        rows.push(json::obj(vec![
            ("fraction", json::num(frac)),
            ("static_mean", json::num(sm)),
            ("static_std", json::num(ss)),
            ("pls", json::num(pm)),
            ("dpquant", json::num(ours)),
        ]));
    }
    println!("Fig 5 — ablation: PLS beats static; PLS+LLP best, gap grows with k");
    table.print();
    save_json("fig5", Json::Arr(rows))
}
