//! Table experiments (Tables 1, 2, 4, 6, 8, 9, 10, 11, 12) plus the
//! accuracy-vs-ε Pareto view the sweep orchestrator renders.

use super::{save_json, ExpCtx};
use crate::cli::Args;
use crate::config::OptimizerKind;
use crate::metrics::{mean_std, Table};
use crate::util::error::Result;
use crate::util::json::{self, Json};

/// One sweep outcome for the Pareto view: higher accuracy and lower ε
/// are both better.
pub struct SweepRow {
    /// Row label (config summary).
    pub label: String,
    /// Best accuracy of the run (higher is better).
    pub accuracy: f64,
    /// ε consumed by the run (lower is better).
    pub epsilon: f64,
}

/// Which rows sit on the (accuracy ↑, ε ↓) Pareto frontier: row `i` is
/// on it iff no other row has `ε ≤ ε_i` and `acc ≥ acc_i` with at least
/// one strict. O(n²), fine at sweep scale.
pub fn pareto_flags(rows: &[SweepRow]) -> Vec<bool> {
    rows.iter()
        .map(|a| {
            !rows.iter().any(|b| {
                b.epsilon <= a.epsilon
                    && b.accuracy >= a.accuracy
                    && (b.epsilon < a.epsilon || b.accuracy > a.accuracy)
            })
        })
        .collect()
}

/// Render sweep outcomes sorted by ε, frontier rows starred — the
/// Fig.-4-style "which configs are worth running" summary.
pub fn pareto_table(rows: &[SweepRow]) -> Table {
    let flags = pareto_flags(rows);
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by(|&a, &b| {
        rows[a]
            .epsilon
            .total_cmp(&rows[b].epsilon)
            .then(rows[b].accuracy.total_cmp(&rows[a].accuracy))
    });
    let mut t = Table::new(&["point", "best acc", "final eps", "pareto"]);
    for i in order {
        t.row(vec![
            rows[i].label.clone(),
            format!("{:.4}", rows[i].accuracy),
            format!("{:.3}", rows[i].epsilon),
            if flags[i] { "*".to_string() } else { String::new() },
        ]);
    }
    t
}

/// Shared engine for the Table-1 family: baseline (static random, N
/// seeds) vs DPQuant at each (ε, fraction) cell.
fn budget_table(
    ctx: &ExpCtx,
    name: &str,
    epsilons: &[f64],
    fracs: &[f64],
    extra: impl Fn(&mut crate::config::TrainConfig) + Copy,
) -> Result<()> {
    let mut table = Table::new(&[
        "eps target",
        "frac",
        "baseline acc",
        "baseline eps",
        "ours acc",
        "ours eps",
    ]);
    let mut rows = Vec::new();
    for &eps in epsilons {
        for &frac in fracs {
            let (base_accs, base_eps) = ctx.sweep("static_random", frac, |c| {
                c.target_epsilon = Some(eps);
                extra(c);
            })?;
            let (bm, bs) = mean_std(&base_accs);
            let mut cfg = ctx.base.clone();
            cfg.scheduler = "dpquant".into();
            cfg.quant_fraction = frac;
            cfg.target_epsilon = Some(eps);
            extra(&mut cfg);
            let res = ctx.run_cfg(&cfg, false)?;
            let (ours, ours_eps) = (res.record.best_accuracy, res.record.final_epsilon);
            table.row(vec![
                format!("{eps}"),
                format!("{frac:.2}"),
                format!("{:.4}±{:.4}", bm, bs),
                format!("{base_eps:.2}"),
                format!("{ours:.4}"),
                format!("{ours_eps:.2}"),
            ]);
            rows.push(json::obj(vec![
                ("eps_target", json::num(eps)),
                ("fraction", json::num(frac)),
                ("baseline_mean", json::num(bm)),
                ("baseline_std", json::num(bs)),
                ("baseline_eps", json::num(base_eps)),
                ("ours", json::num(ours)),
                ("ours_eps", json::num(ours_eps)),
            ]));
        }
    }
    table.print();
    save_json(name, Json::Arr(rows))
}

/// Table 1: accuracy × {ε = 4, 8} × {50, 75, 90}% quantized.
pub fn tab1(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    println!("Table 1 — model quality across privacy levels (DP-SGD)");
    budget_table(&ctx, "tab1", &[4.0, 8.0], &[0.5, 0.75, 0.9], |_| {})?;
    println!("expect: ours ≥ baseline mean (typically ≥ +1σ at 75/90%), ε within budget");
    Ok(())
}

/// Table 2 (A.1): raw gradient-norm range vs batch size — negligible
/// batch-size effect.
pub fn tab2(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let mut table = Table::new(&["batch", "norm-range mean", "norm-range std"]);
    let mut rows = Vec::new();
    for &b in &[16usize, 32, 64, 128] {
        let mut cfg = ctx.base.clone();
        cfg.scheduler = "none".into();
        cfg.batch_size = b;
        let res = ctx.run_cfg(&cfg, true)?;
        // "Range" per step: max raw per-sample norm (the spread of raw
        // gradient magnitudes the quantizer must cover).
        let (m, s) = mean_std(&res.trace.raw_norm_max);
        table.row(vec![b.to_string(), format!("{m:.4}"), format!("{s:.4}")]);
        rows.push(json::obj(vec![
            ("batch", json::num(b as f64)),
            ("mean", json::num(m)),
            ("std", json::num(s)),
        ]));
    }
    println!("Table 2 — gradient norm range vs batch size (expect: flat)");
    table.print();
    save_json("tab2", Json::Arr(rows))
}

/// Table 4 (A.3): the extreme ε = 1 budget (σ and σ_measure raised).
pub fn tab4(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    println!("Table 4 — strict budget ε = 1 (σ = 2.0, σ_measure = 1.0)");
    budget_table(&ctx, "tab4", &[1.0], &[0.5, 0.75, 0.9], |c| {
        c.noise_multiplier = 2.0;
        c.sigma_measure = 1.0;
    })?;
    println!("expect: DPQuant still beats the static baseline at ε = 1");
    Ok(())
}

/// Table 6 (A.5): DP-Adam (lr 0.01) instead of DP-SGD.
pub fn tab6(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    println!("Table 6 — DP-Adam: DPQuant vs static random baseline");
    budget_table(&ctx, "tab6", &[6.0], &[0.5, 0.75, 0.9], |c| {
        c.optimizer = OptimizerKind::Adam;
        c.lr = 0.01;
    })?;
    println!("expect: same ordering as DP-SGD; largest gains at 75/90%");
    Ok(())
}

/// Table 8 (A.6): naive full quantization under DP — the headline
/// degradation motivating the paper.
pub fn tab8(args: &Args) -> Result<()> {
    let mut table = Table::new(&["model/dataset", "fp baseline", "all-LUQ4", "delta"]);
    let mut rows = Vec::new();
    let combos = [
        ("miniconvnet", "gtsrb"),
        ("miniconvnet", "cifar"),
        ("miniresnet", "gtsrb"),
    ];
    for (model, dataset) in combos {
        let mut sub = args.clone();
        sub.options.insert("model".into(), model.into());
        sub.options.insert("dataset".into(), dataset.into());
        let ctx = ExpCtx::open(&sub, model, dataset, "luq4")?;
        let (fp, _) = ctx.sweep("none", 0.0, |_| {})?;
        let (allq, _) = ctx.sweep("all", 1.0, |_| {})?;
        let (fm, _) = mean_std(&fp);
        let (am, _) = mean_std(&allq);
        table.row(vec![
            format!("{model}/{dataset}"),
            format!("{fm:.4}"),
            format!("{am:.4}"),
            format!("{:+.4}", am - fm),
        ]);
        rows.push(json::obj(vec![
            ("model", json::s(model)),
            ("dataset", json::s(dataset)),
            ("fp", json::num(fm)),
            ("all_quant", json::num(am)),
        ]));
    }
    println!("Table 8 — DP-SGD: fp32 vs fully-quantized LUQ-FP4");
    table.print();
    println!("expect: clear degradation under full quantization (paper: −4% to −41%)");
    save_json("tab8", Json::Arr(rows))
}

/// Table 9 (A.7): temperature β sensitivity.
pub fn tab9(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let betas = [0.1, 1.0, 4.86, 10.57, 50.0];
    let fracs = [0.5, 0.9];
    let mut table = Table::new(&["frac", "beta", "acc"]);
    let mut rows = Vec::new();
    for &frac in &fracs {
        for &beta in &betas {
            let mut cfg = ctx.base.clone();
            cfg.scheduler = "dpquant".into();
            cfg.quant_fraction = frac;
            cfg.beta = beta;
            let acc = ctx.run_cfg(&cfg, false)?.record.best_accuracy;
            table.row(vec![
                format!("{frac:.2}"),
                format!("{beta}"),
                format!("{acc:.4}"),
            ]);
            rows.push(json::obj(vec![
                ("fraction", json::num(frac)),
                ("beta", json::num(beta)),
                ("acc", json::num(acc)),
            ]));
        }
    }
    println!("Table 9 — β sensitivity (expect: moderate-to-high β beats β→0)");
    table.print();
    save_json("tab9", Json::Arr(rows))
}

/// Table 10 (A.8): EMA ablation.
pub fn tab10(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "cifar", "luq4")?;
    let mut table = Table::new(&["frac", "with EMA", "without EMA"]);
    let mut rows = Vec::new();
    for &frac in &[0.5, 0.75, 0.9] {
        let mut cfg = ctx.base.clone();
        cfg.scheduler = "dpquant".into();
        cfg.quant_fraction = frac;
        let with = ctx.run_cfg(&cfg, false)?.record.best_accuracy;
        cfg.ema_enabled = false;
        let without = ctx.run_cfg(&cfg, false)?.record.best_accuracy;
        table.row(vec![
            format!("{frac:.2}"),
            format!("{with:.4}"),
            format!("{without:.4}"),
        ]);
        rows.push(json::obj(vec![
            ("fraction", json::num(frac)),
            ("with_ema", json::num(with)),
            ("without_ema", json::num(without)),
        ]));
    }
    println!("Table 10 — EMA ablation (expect: EMA ≥ no-EMA across budgets)");
    table.print();
    save_json("tab10", Json::Arr(rows))
}

/// Table 11 (A.9.1): FP8 — no meaningful DP degradation, so scheduling
/// matters little.
pub fn tab11(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniresnet", "cifar", "fp8")?;
    println!("Table 11 — FP8-E5M2 (expect: baseline ≈ ours; quantization is benign)");
    budget_table(&ctx, "tab11", &[4.0], &[0.5, 0.75, 0.9], |_| {})
}

/// Adaptive-policy Pareto: the four adaptive-DP policies (DESIGN.md
/// §16) under the same substrate and base knobs, rendered as the
/// accuracy-vs-ε Pareto table. Dynamic policies shift where a run
/// lands on the frontier — noise decay and rate schedules trade ε for
/// accuracy, per-layer LR moves accuracy at identical ε (pure
/// post-processing).
pub fn policy(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let variants: [(&str, fn(&mut crate::config::TrainConfig)); 4] = [
        ("static", |_| {}),
        ("noise_decay", |c| {
            c.policy = "noise_decay".into();
            c.noise_final = c.noise_multiplier * 1.5;
        }),
        ("rate_schedule", |c| {
            c.policy = "rate_schedule".into();
            c.rate_final = c.sample_rate() / 2.0;
        }),
        ("layer_lr", |c| {
            c.policy = "layer_lr".into();
            c.layer_lr_strength = 0.75;
        }),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (label, tweak) in variants {
        let mut cfg = ctx.base.clone();
        cfg.scheduler = "dpquant".into();
        cfg.quant_fraction = 0.75;
        tweak(&mut cfg);
        let res = ctx.run_cfg(&cfg, false)?;
        rows.push(SweepRow {
            label: label.into(),
            accuracy: res.record.best_accuracy,
            epsilon: res.record.final_epsilon,
        });
        out.push(json::obj(vec![
            ("policy", json::s(label)),
            ("acc", json::num(res.record.best_accuracy)),
            ("eps", json::num(res.record.final_epsilon)),
        ]));
    }
    println!("Adaptive-policy Pareto — static vs noise_decay vs rate_schedule vs layer_lr");
    pareto_table(&rows).print();
    println!(
        "expect: layer_lr at the static ε (post-processing); noise_decay/rate_schedule \
         at lower ε with competitive accuracy"
    );
    save_json("policy_pareto", Json::Arr(out))
}

/// Table 12 (A.9.2): uniform INT4 stochastic rounding.
pub fn tab12(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniresnet", "cifar", "uniform4")?;
    println!(
        "Table 12 — uniform 4-bit (expect: degradation like LUQ-FP4; ours ≥ baseline at high frac)"
    );
    budget_table(&ctx, "tab12", &[4.5], &[0.5, 0.75, 0.9], |_| {})
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(label: &str, accuracy: f64, epsilon: f64) -> SweepRow {
        SweepRow {
            label: label.into(),
            accuracy,
            epsilon,
        }
    }

    #[test]
    fn pareto_frontier_dominance() {
        let rows = [
            row("a", 0.9, 2.0), // frontier
            row("b", 0.5, 3.0), // dominated by a
            row("c", 0.4, 1.0), // frontier: cheapest eps
            row("d", 0.9, 2.5), // dominated by a (same acc, worse eps)
            row("e", 0.95, 8.0), // frontier: best acc
        ];
        assert_eq!(pareto_flags(&rows), vec![true, false, true, false, true]);
    }

    #[test]
    fn pareto_duplicates_both_survive() {
        // Two identical points dominate each other weakly but not
        // strictly, so both stay on the frontier.
        let rows = [row("a", 0.7, 2.0), row("b", 0.7, 2.0)];
        assert_eq!(pareto_flags(&rows), vec![true, true]);
    }

    #[test]
    fn pareto_table_sorted_by_epsilon() {
        let rows = [row("hi", 0.9, 5.0), row("lo", 0.4, 1.0)];
        let s = pareto_table(&rows).render();
        let lo = s.find("lo").unwrap();
        let hi = s.find("hi").unwrap();
        assert!(lo < hi, "rows must sort by eps:\n{s}");
    }
}
