//! Perf-trend engine over `dpquant-bench` snapshots (DESIGN.md §17.3).
//!
//! The ROADMAP calls the committed `BENCH_*.json` files the repo's
//! reviewable perf record; this module makes that record *enforceable*.
//! `dpquant bench diff OLD NEW` compares two snapshots key by key and
//! exits nonzero when a gated metric regresses past its threshold, so
//! the CI `bench-json` job fails loudly on a PR that silently slows the
//! hot path. `dpquant bench trend A B C...` walks a snapshot sequence
//! (oldest first) and renders the per-key trajectory, gating the
//! first→last movement with the same thresholds.
//!
//! Gating policy (per top-level group of the bench doc):
//!
//! | group                      | direction        | gate               |
//! |----------------------------|------------------|--------------------|
//! | `kernels_ns`               | lower is better  | **fail** > --fail-threshold (default 10%) |
//! | `submit_ms`, `wait_ms`     | lower is better  | warn > --warn-threshold (default 5%) |
//! | `steps_per_sec`            | higher is better | warn on drop > --warn-threshold |
//! | `blocked_speedup`          | higher is better | warn on drop > --warn-threshold |
//! | everything else            | informational    | never gates        |
//!
//! Keys present in only one snapshot are reported (`added`/`removed`)
//! but never gate — renaming a kernel must not brick CI. Snapshots
//! marked `"quick": true` are compared like any others (CI diffs
//! same-machine quick emits) but flagged in the output, since quick
//! numbers are not comparable across machines.

use crate::cli::Args;
use crate::metrics::Table;
use crate::util::error::{ensure, err, Result};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

use super::perf::{BENCH_FORMAT, BENCH_VERSION};

/// A parsed `dpquant-bench` document: every top-level object whose
/// members are all numbers becomes a metric group.
pub struct Snapshot {
    /// Where it was loaded from (for messages).
    pub path: String,
    /// Bench family (`native`, `serve`; absent = `native`).
    pub family: String,
    /// Was it emitted under `DPQUANT_BENCH_QUICK`?
    pub quick: bool,
    /// Is it a hand-provisioned placeholder rather than a measurement?
    pub provisional: bool,
    /// group → key → value.
    pub groups: BTreeMap<String, BTreeMap<String, f64>>,
}

/// Load and structurally validate one snapshot (format/version pins;
/// deeper schema checks belong to `bench --check`).
pub fn load(path: &str) -> Result<Snapshot> {
    let text =
        std::fs::read_to_string(path).map_err(|e| err!("bench trend: cannot read {path}: {e}"))?;
    let doc =
        json::parse(&text).map_err(|e| err!("bench trend: {path}: invalid JSON: {e}"))?;
    let fmt = doc.get("format").and_then(Json::as_str).unwrap_or("");
    ensure!(
        fmt == BENCH_FORMAT,
        "bench trend: {path}: format {fmt:?} != {BENCH_FORMAT:?}"
    );
    let ver = doc.get("version").and_then(Json::as_f64).unwrap_or(0.0);
    ensure!(
        ver == BENCH_VERSION as f64,
        "bench trend: {path}: version {ver} != {BENCH_VERSION}"
    );
    let obj = doc
        .as_obj()
        .ok_or_else(|| err!("bench trend: {path}: top level is not an object"))?;
    let mut groups = BTreeMap::new();
    for (name, value) in obj {
        if let Some(members) = value.as_obj() {
            let mut metrics = BTreeMap::new();
            let mut all_numbers = !members.is_empty();
            for (k, v) in members {
                match v.as_f64() {
                    Some(x) if x.is_finite() => {
                        metrics.insert(k.clone(), x);
                    }
                    _ => {
                        all_numbers = false;
                        break;
                    }
                }
            }
            if all_numbers {
                groups.insert(name.clone(), metrics);
            }
        }
    }
    ensure!(
        !groups.is_empty(),
        "bench trend: {path}: no numeric metric groups found"
    );
    Ok(Snapshot {
        path: path.to_string(),
        family: doc
            .get("family")
            .and_then(Json::as_str)
            .unwrap_or("native")
            .to_string(),
        quick: doc.get("quick").and_then(Json::as_bool).unwrap_or(false),
        provisional: doc
            .get("provisional")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        groups,
    })
}

/// How a group's movement is judged.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Gate {
    /// Lower is better; an increase past the fail threshold fails.
    FailOnIncrease,
    /// Lower is better; an increase past the warn threshold warns.
    WarnOnIncrease,
    /// Higher is better; a drop past the warn threshold warns.
    WarnOnDrop,
    /// Reported, never gated.
    Info,
}

fn gate_for(group: &str) -> Gate {
    match group {
        "kernels_ns" => Gate::FailOnIncrease,
        "submit_ms" | "wait_ms" => Gate::WarnOnIncrease,
        "steps_per_sec" | "blocked_speedup" => Gate::WarnOnDrop,
        _ => Gate::Info,
    }
}

/// One compared key.
pub struct Delta {
    /// Metric group (`kernels_ns`, ...).
    pub group: String,
    /// Metric key within the group.
    pub key: String,
    /// Old value (`None` = key added in the new snapshot).
    pub old: Option<f64>,
    /// New value (`None` = key removed).
    pub new: Option<f64>,
    /// Percent change new vs old, when both sides exist and old > 0.
    pub pct: Option<f64>,
    /// Rendered status cell (`ok`, `FAIL`, `warn`, ...).
    pub status: &'static str,
}

/// The full comparison of two snapshots.
pub struct Comparison {
    /// Every compared key, group-major.
    pub rows: Vec<Delta>,
    /// Gated keys past the fail threshold.
    pub regressions: usize,
    /// Gated keys past the warn threshold.
    pub warnings: usize,
}

/// Compare `new` against `old` with percent thresholds.
pub fn compare(old: &Snapshot, new: &Snapshot, fail_pct: f64, warn_pct: f64) -> Comparison {
    let mut rows = Vec::new();
    let mut regressions = 0usize;
    let mut warnings = 0usize;
    let group_names: BTreeMap<&String, ()> = old
        .groups
        .keys()
        .chain(new.groups.keys())
        .map(|g| (g, ()))
        .collect();
    for (group, ()) in group_names {
        let empty = BTreeMap::new();
        let o = old.groups.get(group).unwrap_or(&empty);
        let n = new.groups.get(group).unwrap_or(&empty);
        let keys: BTreeMap<&String, ()> = o.keys().chain(n.keys()).map(|k| (k, ())).collect();
        let gate = gate_for(group);
        for (key, ()) in keys {
            let (ov, nv) = (o.get(key).copied(), n.get(key).copied());
            let (pct, status) = match (ov, nv) {
                (Some(a), Some(b)) if a > 0.0 => {
                    let pct = (b / a - 1.0) * 100.0;
                    let status = match gate {
                        Gate::FailOnIncrease if pct > fail_pct => {
                            regressions += 1;
                            "FAIL"
                        }
                        Gate::FailOnIncrease | Gate::WarnOnIncrease if pct > warn_pct => {
                            warnings += 1;
                            "warn"
                        }
                        Gate::WarnOnDrop if pct < -warn_pct => {
                            warnings += 1;
                            "warn"
                        }
                        Gate::Info => "",
                        _ => "ok",
                    };
                    (Some(pct), status)
                }
                (Some(_), Some(_)) => (None, "n/a"),
                (Some(_), None) => (None, "removed"),
                (None, Some(_)) => (None, "added"),
                (None, None) => (None, ""),
            };
            rows.push(Delta {
                group: group.clone(),
                key: key.clone(),
                old: ov,
                new: nv,
                pct,
                status,
            });
        }
    }
    Comparison {
        rows,
        regressions,
        warnings,
    }
}

fn fmt_val(v: Option<f64>) -> String {
    match v {
        Some(x) if x.abs() >= 100.0 => format!("{x:.0}"),
        Some(x) => format!("{x:.3}"),
        None => "-".into(),
    }
}

fn print_comparison(cmp: &Comparison) {
    let mut t = Table::new(&["group", "key", "old", "new", "delta %", "status"]);
    for d in &cmp.rows {
        t.row(vec![
            d.group.clone(),
            d.key.clone(),
            fmt_val(d.old),
            fmt_val(d.new),
            d.pct.map_or("-".into(), |p| format!("{p:+.1}")),
            d.status.into(),
        ]);
    }
    t.print();
}

fn thresholds(args: &Args) -> Result<(f64, f64)> {
    let fail = args.f64_or("fail-threshold", 10.0)?;
    let warn = args.f64_or("warn-threshold", 5.0)?;
    ensure!(
        fail.is_finite() && fail >= 0.0 && warn.is_finite() && warn >= 0.0,
        "bench thresholds must be finite non-negative percentages"
    );
    Ok((fail, warn))
}

fn note_flags(s: &Snapshot) {
    if s.quick {
        println!("note: {} is a quick emit (numbers only comparable on one machine)", s.path);
    }
    if s.provisional {
        println!("note: {} is marked provisional (placeholder, not a measurement)", s.path);
    }
}

/// Entry point for `dpquant bench diff|trend` (dispatched from main).
pub fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("diff") => cmd_diff(args),
        Some("trend") => cmd_trend(args),
        _ => Err(err!("usage: dpquant bench <diff OLD NEW|trend A B [C...]>")),
    }
}

/// `dpquant bench diff OLD NEW` — per-key delta table, nonzero exit on
/// gated regression.
fn cmd_diff(args: &Args) -> Result<()> {
    let usage = "usage: dpquant bench diff OLD NEW [--fail-threshold PCT] [--warn-threshold PCT]";
    let old_path = args.positional.get(2).ok_or_else(|| err!("{usage}"))?;
    let new_path = args.positional.get(3).ok_or_else(|| err!("{usage}"))?;
    let (fail_pct, warn_pct) = thresholds(args)?;
    let old = load(old_path)?;
    let new = load(new_path)?;
    ensure!(
        old.family == new.family,
        "bench diff: cannot compare family {:?} ({}) against {:?} ({})",
        old.family,
        old.path,
        new.family,
        new.path
    );
    note_flags(&old);
    note_flags(&new);
    let cmp = compare(&old, &new, fail_pct, warn_pct);
    print_comparison(&cmp);
    println!(
        "bench diff: {} keys, {} regression(s) > {fail_pct}%, {} warning(s) > {warn_pct}%",
        cmp.rows.len(),
        cmp.regressions,
        cmp.warnings
    );
    ensure!(
        cmp.regressions == 0,
        "bench diff: {} gated metric(s) regressed more than {fail_pct}% \
         ({new_path} vs {old_path})",
        cmp.regressions
    );
    Ok(())
}

/// `dpquant bench trend A B [C...]` — per-key trajectory across a
/// snapshot sequence (oldest first); gates the first→last movement.
fn cmd_trend(args: &Args) -> Result<()> {
    let usage = "usage: dpquant bench trend A B [C...] [--fail-threshold PCT] [--warn-threshold PCT]";
    let paths: Vec<&String> = args.positional.iter().skip(2).collect();
    ensure!(paths.len() >= 2, "{usage}");
    let (fail_pct, warn_pct) = thresholds(args)?;
    let snaps = paths.iter().map(|p| load(p)).collect::<Result<Vec<_>>>()?;
    for s in &snaps {
        ensure!(
            s.family == snaps[0].family,
            "bench trend: mixed families ({} is {:?}, {} is {:?})",
            snaps[0].path,
            snaps[0].family,
            s.path,
            s.family
        );
        note_flags(s);
    }

    // Trajectory per key: one column per snapshot plus first→last delta.
    let mut header: Vec<String> = vec!["group".into(), "key".into()];
    for (i, s) in snaps.iter().enumerate() {
        header.push(format!("[{i}] {}", short_name(&s.path)));
    }
    header.push("first->last %".into());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&header_refs);
    let first = &snaps[0];
    let last = &snaps[snaps.len() - 1];
    for (group, keys) in &first.groups {
        for key in keys.keys() {
            let mut row = vec![group.clone(), key.clone()];
            for s in &snaps {
                row.push(fmt_val(s.groups.get(group).and_then(|g| g.get(key)).copied()));
            }
            let pct = match (
                first.groups.get(group).and_then(|g| g.get(key)),
                last.groups.get(group).and_then(|g| g.get(key)),
            ) {
                (Some(&a), Some(&b)) if a > 0.0 => Some((b / a - 1.0) * 100.0),
                _ => None,
            };
            row.push(pct.map_or("-".into(), |p| format!("{p:+.1}")));
            t.row(row);
        }
    }
    t.print();

    // Per-transition gate counts, then the first→last gate.
    for w in snaps.windows(2) {
        let cmp = compare(&w[0], &w[1], fail_pct, warn_pct);
        println!(
            "{} -> {}: {} regression(s), {} warning(s)",
            short_name(&w[0].path),
            short_name(&w[1].path),
            cmp.regressions,
            cmp.warnings
        );
    }
    let overall = compare(first, last, fail_pct, warn_pct);
    ensure!(
        overall.regressions == 0,
        "bench trend: {} gated metric(s) regressed more than {fail_pct}% from {} to {}",
        overall.regressions,
        first.path,
        last.path
    );
    Ok(())
}

fn short_name(path: &str) -> String {
    std::path::Path::new(path)
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dpquant_trend_{tag}_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn snapshot_text(matmul_ns: f64, fp32_sps: f64) -> String {
        format!(
            "{{\"format\":\"{BENCH_FORMAT}\",\"version\":{BENCH_VERSION},\"quick\":false,\
             \"provisional\":false,\"reps\":40,\"batch\":32,\
             \"kernels_ns\":{{\"matmul_96x256x96_blocked\":{matmul_ns},\"quant_luq4_per_elem\":4.2}},\
             \"blocked_speedup\":{{\"matmul_96x256x96\":3.1}},\
             \"steps_per_sec\":{{\"fp32\":{fp32_sps},\"luq4\":20.0}},\
             \"fp32_vs_quantized\":{{\"luq4\":1.4}}}}\n"
        )
    }

    fn write_snap(tag: &str, matmul_ns: f64, fp32_sps: f64) -> String {
        let path = tmp(tag);
        std::fs::write(&path, snapshot_text(matmul_ns, fp32_sps)).unwrap();
        path
    }

    #[test]
    fn identical_snapshots_have_no_regressions() {
        let a = write_snap("id_a", 1000.0, 25.0);
        let b = write_snap("id_b", 1000.0, 25.0);
        let cmp = compare(&load(&a).unwrap(), &load(&b).unwrap(), 10.0, 5.0);
        assert_eq!(cmp.regressions, 0);
        assert_eq!(cmp.warnings, 0);
        assert!(cmp.rows.iter().all(|d| d.pct == Some(0.0) || d.pct.is_none()));
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn kernel_ns_increase_past_threshold_fails() {
        let a = write_snap("reg_a", 1000.0, 25.0);
        let b = write_snap("reg_b", 1200.0, 25.0); // +20% kernel ns
        let cmp = compare(&load(&a).unwrap(), &load(&b).unwrap(), 10.0, 5.0);
        assert_eq!(cmp.regressions, 1);
        let d = cmp
            .rows
            .iter()
            .find(|d| d.key == "matmul_96x256x96_blocked")
            .unwrap();
        assert_eq!(d.status, "FAIL");
        assert!((d.pct.unwrap() - 20.0).abs() < 1e-9);
        // Same movement under a 25% threshold is merely a warning.
        let cmp = compare(&load(&a).unwrap(), &load(&b).unwrap(), 25.0, 5.0);
        assert_eq!(cmp.regressions, 0);
        assert_eq!(cmp.warnings, 1);
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn steps_per_sec_drop_warns_but_never_fails() {
        let a = write_snap("sps_a", 1000.0, 25.0);
        let b = write_snap("sps_b", 1000.0, 20.0); // -20% steps/sec
        let cmp = compare(&load(&a).unwrap(), &load(&b).unwrap(), 10.0, 5.0);
        assert_eq!(cmp.regressions, 0);
        assert_eq!(cmp.warnings, 1);
        let d = cmp.rows.iter().find(|d| d.key == "fp32").unwrap();
        assert_eq!(d.status, "warn");
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn added_and_removed_keys_report_without_gating() {
        let a = write_snap("keys_a", 1000.0, 25.0);
        let path_b = tmp("keys_b");
        // Rename the matmul kernel: old key removed, new key added.
        std::fs::write(
            &path_b,
            snapshot_text(1000.0, 25.0)
                .replace("matmul_96x256x96_blocked", "matmul_96x256x96_tiled"),
        )
        .unwrap();
        let cmp = compare(&load(&a).unwrap(), &load(&path_b).unwrap(), 10.0, 5.0);
        assert_eq!(cmp.regressions, 0);
        assert!(cmp.rows.iter().any(|d| d.status == "removed"));
        assert!(cmp.rows.iter().any(|d| d.status == "added"));
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&path_b).ok();
    }

    #[test]
    fn load_rejects_wrong_format() {
        let path = tmp("badfmt");
        std::fs::write(&path, "{\"format\":\"other\",\"version\":1}\n").unwrap();
        let e = load(&path).unwrap_err().to_string();
        assert!(e.contains("format"), "{e}");
        std::fs::remove_file(&path).ok();
    }
}
