//! Tiny argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `dpquant <command> [subcommand] [--key value]... [--flag]...`
//! Values are parsed on demand with typed accessors.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (command, subcommand, ...).
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.get(1).map(String::as_str)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} '{v}': {e}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} '{v}': {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} '{v}': {e}")),
        }
    }

    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name} '{v}': {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_options_flags() {
        let a = parse("exp tab1 --epochs 30 --model miniresnet --verbose --lr=0.5");
        assert_eq!(a.command(), Some("exp"));
        assert_eq!(a.subcommand(), Some("tab1"));
        assert_eq!(a.usize_or("epochs", 0).unwrap(), 30);
        assert_eq!(a.str_or("model", ""), "miniresnet");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn option_followed_by_flag() {
        let a = parse("train --fast --seed 7");
        assert!(a.has_flag("fast"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("x --bias -0.5");
        assert_eq!(a.f64_or("bias", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse("x --epochs abc");
        let err = a.usize_or("epochs", 0).unwrap_err();
        assert!(err.contains("epochs"), "{err}");
    }

    #[test]
    fn missing_defaults() {
        let a = parse("train");
        assert_eq!(a.f64_or("lr", 0.25).unwrap(), 0.25);
        assert_eq!(a.f64_opt("target_epsilon").unwrap(), None);
    }
}
