//! Tiny argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `dpquant <command> [subcommand] [--key value]... [--flag]...`
//! Values are parsed on demand with typed accessors.
//!
//! Every accessor returns [`ArgError`], which implements
//! `std::error::Error`, so call sites propagate with plain `?` into
//! `util::error::Error` — no `map_err` needed. [`Args::require_known`]
//! rejects misspelled options (`--quant-fracton`) instead of silently
//! ignoring them and running the wrong experiment.

use std::collections::BTreeMap;
use std::fmt;

/// A command-line parsing/validation failure. Converts into
/// `util::error::Error` through the blanket `std::error::Error` impl.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgError(String);

impl ArgError {
    /// Ad-hoc argument error from anything printable.
    pub fn new<M: fmt::Display>(msg: M) -> Self {
        Self(msg.to_string())
    }
}

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ArgError {}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (command, subcommand, ...).
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, ArgError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(ArgError::new("bare '--' not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process's own command line (`std::env::args`).
    pub fn from_env() -> Result<Self, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    /// The first positional argument — the top-level command.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
    /// The second positional argument (e.g. the `exp` id or `job` verb).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.get(1).map(String::as_str)
    }

    /// Was the bare flag `--name` passed?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The raw value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// `--name` as a string, or `default`.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// `--name` parsed as f64, or `default`; parse failures are errors.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| ArgError::new(format!("--{name} '{v}': {e}"))),
        }
    }

    /// `--name` parsed as usize, or `default`; parse failures are errors.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| ArgError::new(format!("--{name} '{v}': {e}"))),
        }
    }

    /// `--name` parsed as u64, or `default`; parse failures are errors.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| ArgError::new(format!("--{name} '{v}': {e}"))),
        }
    }

    /// `--name` parsed as f64, `None` if absent; parse failures are errors.
    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>, ArgError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| ArgError::new(format!("--{name} '{v}': {e}"))),
        }
    }

    /// `--name` parsed as usize, `None` if absent; parse failures are errors.
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>, ArgError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| ArgError::new(format!("--{name} '{v}': {e}"))),
        }
    }

    /// Validate that every parsed option/flag is one the current command
    /// understands. A misspelled `--quant-fracton 0.9` otherwise runs a
    /// full-precision job and spends the privacy budget on the wrong
    /// experiment — this makes it a hard error, with a nearest-match
    /// suggestion when one is close.
    pub fn require_known(
        &self,
        command: &str,
        options: &[&str],
        flags: &[&str],
    ) -> Result<(), ArgError> {
        for key in self.options.keys() {
            if options.iter().any(|&o| o == key) {
                continue;
            }
            if flags.iter().any(|&f| f == key) {
                return Err(ArgError::new(format!(
                    "'{command}': --{key} is a flag and does not take a value"
                )));
            }
            return Err(unknown_key_error(command, key, "option", options, flags));
        }
        for key in &self.flags {
            if flags.iter().any(|f| f == key) {
                continue;
            }
            if options.iter().any(|o| o == key) {
                return Err(ArgError::new(format!(
                    "'{command}': option --{key} requires a value"
                )));
            }
            return Err(unknown_key_error(command, key, "flag", options, flags));
        }
        Ok(())
    }
}

fn unknown_key_error(
    command: &str,
    key: &str,
    kind: &str,
    options: &[&str],
    flags: &[&str],
) -> ArgError {
    let mut msg = format!("'{command}': unknown {kind} --{key}");
    if let Some(near) = nearest(key, options.iter().chain(flags.iter()).copied()) {
        msg.push_str(&format!(" (did you mean --{near}?)"));
    }
    ArgError::new(msg)
}

/// Error for an unknown top-level (or nested) command, with a
/// did-you-mean suggestion when a known command is within 3 edits —
/// the command-level mirror of [`Args::require_known`]'s flag-level
/// behavior, so `dpquant sweeep` points at `sweep` the same way
/// `--quant-fracton` points at `--quant-fraction`.
pub fn unknown_command_error(what: &str, cmd: &str, known: &[&str]) -> ArgError {
    let mut msg = format!("unknown {what} '{cmd}'");
    if let Some(near) = nearest(cmd, known.iter().copied()) {
        msg.push_str(&format!(" (did you mean '{near}'?)"));
    }
    ArgError::new(msg)
}

/// Closest known key by edit distance, if within 3 edits. Public so
/// other keyed front-ends (the sweep grid parser) can offer the same
/// did-you-mean suggestions.
pub fn nearest<'a>(key: &str, known: impl Iterator<Item = &'a str>) -> Option<&'a str> {
    known
        .map(|k| (edit_distance(key, k), k))
        .min()
        .filter(|&(d, _)| d <= 3)
        .map(|(_, k)| k)
}

/// Plain Levenshtein distance (keys are short; O(nm) is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_options_flags() {
        let a = parse("exp tab1 --epochs 30 --model miniresnet --verbose --lr=0.5");
        assert_eq!(a.command(), Some("exp"));
        assert_eq!(a.subcommand(), Some("tab1"));
        assert_eq!(a.usize_or("epochs", 0).unwrap(), 30);
        assert_eq!(a.str_or("model", ""), "miniresnet");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn option_followed_by_flag() {
        let a = parse("train --fast --seed 7");
        assert!(a.has_flag("fast"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("x --bias -0.5");
        assert_eq!(a.f64_or("bias", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse("x --epochs abc");
        let err = a.usize_or("epochs", 0).unwrap_err();
        assert!(err.to_string().contains("epochs"), "{err}");
    }

    #[test]
    fn missing_defaults() {
        let a = parse("train");
        assert_eq!(a.f64_or("lr", 0.25).unwrap(), 0.25);
        assert_eq!(a.f64_opt("target_epsilon").unwrap(), None);
        assert_eq!(a.usize_opt("epochs").unwrap(), None);
    }

    #[test]
    fn known_keys_accepted() {
        let a = parse("train --epochs 3 --stats");
        a.require_known("train", &["epochs", "lr"], &["stats", "quiet"])
            .unwrap();
    }

    #[test]
    fn misspelled_option_rejected_with_suggestion() {
        let a = parse("train --quant-fracton 0.9");
        let err = a
            .require_known("train", &["quant-fraction", "epochs"], &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("quant-fracton"), "{err}");
        assert!(err.contains("did you mean --quant-fraction"), "{err}");
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("train --turbo");
        let err = a
            .require_known("train", &["epochs"], &["stats"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown flag --turbo"), "{err}");
    }

    #[test]
    fn option_missing_value_reported() {
        // `--epochs` at the end of the line parses as a flag; validation
        // recognizes it as a value-taking option and says so.
        let a = parse("train --epochs");
        let err = a
            .require_known("train", &["epochs"], &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("requires a value"), "{err}");
    }

    #[test]
    fn flag_with_value_reported() {
        let a = parse("train --stats yes");
        let err = a
            .require_known("train", &["epochs"], &["stats"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not take a value"), "{err}");
    }

    #[test]
    fn unknown_command_suggests_nearest() {
        let commands = &["train", "eval-only", "accountant", "exp", "sweep", "serve", "job"];
        let msg = unknown_command_error("command", "sweeep", commands).to_string();
        assert!(msg.contains("unknown command 'sweeep'"), "{msg}");
        assert!(msg.contains("did you mean 'sweep'?"), "{msg}");
        let msg = unknown_command_error("command", "serv", commands).to_string();
        assert!(msg.contains("did you mean 'serve'?"), "{msg}");
        // Nothing close: no suggestion at all.
        let msg = unknown_command_error("command", "frobnicate", commands).to_string();
        assert!(!msg.contains("did you mean"), "{msg}");
        // Subcommand flavor for `dpquant job ...`.
        let msg =
            unknown_command_error("job subcommand", "sumbit", &["submit", "list"]).to_string();
        assert!(msg.contains("unknown job subcommand 'sumbit'"), "{msg}");
        assert!(msg.contains("did you mean 'submit'?"), "{msg}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("quant-fracton", "quant-fraction"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
