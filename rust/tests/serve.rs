//! Tier-1 serving tests: the acceptance contract of `rust/src/serve/`.
//!
//! (a) **Determinism through the API**: a config submitted to the
//!     daemon — with THREE jobs running concurrently — finishes with a
//!     `final:` metrics line byte-identical to a direct in-process run
//!     of the same config (the `DPQUANT_THREADS=1 dpquant train`
//!     semantics; daemon workers pin the native backend to one internal
//!     thread exactly like sweep workers).
//! (b) **Durability**: a daemon killed mid-job leaves exactly a
//!     `running` manifest plus the last epoch-boundary checkpoint in
//!     its state dir. We fabricate that precise disk state, start a
//!     daemon over it, and require the recovered job to finish
//!     byte-identical to an uninterrupted run. Terminal jobs must keep
//!     their recorded outcome and ids must keep increasing.
//! (c) **Robustness**: a barrage of malformed HTTP/JSON gets 4xx/5xx
//!     answers (or a clean close) and the daemon keeps serving — it
//!     never panics, and a real job still runs afterwards.
//!
//! Everything runs on `127.0.0.1:0` (ephemeral ports), in-process, with
//! no artifacts — tier-1 like the rest of the native suite.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use dpquant::backend;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{train_with_sink, NullSink, TrainSession};
use dpquant::data;
use dpquant::serve::client::{final_line_from_status, Client};
use dpquant::serve::jobs::config_to_json;
use dpquant::serve::Daemon;
use dpquant::util::json::{self, Json};

const WAIT: Duration = Duration::from_secs(120);
const POLL: Duration = Duration::from_millis(20);

/// A fast real-training config for the native backend (the model/sizes
/// CI's resume-smoke uses).
fn native_cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "logreg".into(),
        backend: "native".into(),
        dataset_size: 192,
        val_size: 64,
        batch_size: 16,
        physical_batch: 64,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

fn mock_cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        backend: "mock".into(),
        dataset_size: 96,
        val_size: 32,
        batch_size: 16,
        physical_batch: 32,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

/// The reference: run the config directly, exactly as a daemon worker
/// would (same executor factory, hence the same 1-thread native
/// pinning), and format the canonical final line.
fn direct_final_line(cfg: &TrainConfig) -> String {
    let (train_ds, val_ds) =
        data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed).unwrap();
    let exec =
        backend::open_sweep_executor(cfg, train_ds.example_numel, train_ds.n_classes).unwrap();
    let (record, _weights, _accountant) =
        train_with_sink(exec.as_ref(), cfg, &train_ds, &val_ds, &mut NullSink).unwrap();
    record.final_line()
}

fn temp_state_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("dpquant_serve_{tag}_{}", std::process::id()));
    let dir = dir.to_str().unwrap().to_string();
    std::fs::remove_dir_all(&dir).ok();
    dir
}

// ---------------------------------------------------------------------
// (a) API-submitted jobs == direct runs, 3x concurrent
// ---------------------------------------------------------------------

#[test]
fn api_jobs_match_direct_runs_with_three_concurrent() {
    let daemon = Daemon::start("127.0.0.1:0", 3, None).unwrap();
    let client = Client::new(&daemon.addr());

    // Three distinct configs in flight at once on three workers.
    let cfgs: Vec<TrainConfig> = (0..3).map(|i| native_cfg(i, 2)).collect();
    let ids: Vec<u64> = cfgs.iter().map(|c| client.submit(c).unwrap()).collect();
    assert_eq!(ids, vec![1, 2, 3], "ids are monotonically increasing from 1");

    for (id, cfg) in ids.iter().zip(&cfgs) {
        let status = client.wait(*id, WAIT, POLL).unwrap();
        assert_eq!(
            status.get("status").unwrap().as_str(),
            Some("done"),
            "{status}"
        );
        let wire_line = final_line_from_status(&status).unwrap();
        assert_eq!(
            wire_line,
            direct_final_line(cfg),
            "job {id}: the daemon's final metrics must be byte-identical to a direct run"
        );
    }

    // Same config resubmitted -> same bytes again (pure function).
    let again = client.submit(&cfgs[0]).unwrap();
    let status = client.wait(again, WAIT, POLL).unwrap();
    assert_eq!(
        final_line_from_status(&status).unwrap(),
        direct_final_line(&cfgs[0])
    );

    let health = client.healthz().unwrap();
    assert_eq!(health.get("jobs").unwrap().get("done").unwrap().as_usize(), Some(4));
    assert_eq!(health.get("workers").unwrap().as_usize(), Some(3));
    daemon.stop();
}

// ---------------------------------------------------------------------
// (b) kill -9 durability: recover + finish bit-exactly
// ---------------------------------------------------------------------

#[test]
fn restarted_daemon_resumes_killed_job_bit_exact() {
    let dir = temp_state_dir("recover");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = native_cfg(7, 4);

    // Fabricate the exact disk state a daemon killed mid-job leaves
    // behind: the job's manifest still saying "running", and the
    // checkpoint written at the last completed epoch boundary (2 of 4).
    let (train_ds, val_ds) =
        data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed).unwrap();
    let exec =
        backend::open_sweep_executor(&cfg, train_ds.example_numel, train_ds.n_classes).unwrap();
    let mut session = TrainSession::builder(cfg.clone()).build(exec.as_ref(), &train_ds).unwrap();
    for _ in 0..2 {
        session.step_epoch(exec.as_ref(), &train_ds, &val_ds, &mut NullSink).unwrap();
    }
    session.checkpoint(&format!("{dir}/job-1.ck.json")).unwrap();
    let manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(1.0)),
        ("status", json::s("running")),
        ("epochs_completed", json::num(2.0)),
        ("config", config_to_json(&cfg)),
        ("error", Json::Null),
        ("summary", Json::Null),
    ]);
    std::fs::write(format!("{dir}/job-1.json"), manifest.to_string()).unwrap();

    // A job that already finished before the crash: its outcome must
    // survive untouched (and must NOT be re-run).
    let done_manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(2.0)),
        ("status", json::s("done")),
        ("epochs_completed", json::num(1.0)),
        ("config", config_to_json(&mock_cfg(1, 1))),
        ("error", Json::Null),
        (
            "summary",
            json::obj(vec![
                ("final_accuracy", json::num(0.25)),
                ("best_accuracy", json::num(0.25)),
                ("final_epsilon", json::num(1.5)),
                ("analysis_epsilon", json::num(0.0)),
                ("epochs_run", json::num(1.0)),
                ("truncated", Json::Bool(false)),
            ]),
        ),
    ]);
    std::fs::write(format!("{dir}/job-2.json"), done_manifest.to_string()).unwrap();

    // A running job whose cancel was acknowledged just before the
    // crash: recovery must honor the intent (cancelled), not re-run it.
    let cancel_manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(3.0)),
        ("status", json::s("running")),
        ("cancel_requested", Json::Bool(true)),
        ("epochs_completed", json::num(1.0)),
        ("config", config_to_json(&native_cfg(2, 4))),
        ("error", Json::Null),
        ("summary", Json::Null),
    ]);
    std::fs::write(format!("{dir}/job-3.json"), cancel_manifest.to_string()).unwrap();

    // "Restart" the daemon over that state dir.
    let daemon = Daemon::start("127.0.0.1:0", 2, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());

    // The killed job resumes from its checkpoint and finishes with the
    // SAME bytes as an uninterrupted 4-epoch run.
    let status = client.wait(1, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");
    assert_eq!(status.get("recovered").unwrap().as_bool(), Some(true));
    assert_eq!(
        final_line_from_status(&status).unwrap(),
        direct_final_line(&cfg),
        "recovery must be bit-exact vs an uninterrupted run"
    );

    // The pre-crash outcome of job 2 is intact, not re-run.
    let done = client.job_status(2).unwrap();
    assert_eq!(done.get("status").unwrap().as_str(), Some("done"));
    let summary = done.get("summary").unwrap();
    assert_eq!(summary.get("final_epsilon").unwrap().as_f64(), Some(1.5));

    // The acknowledged cancel survived the crash: job 3 is cancelled,
    // never resurrected.
    let cancelled = client.job_status(3).unwrap();
    assert_eq!(cancelled.get("status").unwrap().as_str(), Some("cancelled"));

    // Ids keep increasing past everything recovered.
    let new_id = client.submit(&mock_cfg(9, 1)).unwrap();
    assert_eq!(new_id, 4);
    client.wait(new_id, WAIT, POLL).unwrap();

    daemon.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovery_from_manifest_without_checkpoint_reruns_from_scratch() {
    // Killed after submit but before the first epoch's checkpoint: the
    // manifest exists, no .ck.json does. Recovery re-runs the whole job
    // deterministically.
    let dir = temp_state_dir("fresh");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = native_cfg(11, 2);
    let manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(5.0)),
        ("status", json::s("queued")),
        ("epochs_completed", json::num(0.0)),
        ("config", config_to_json(&cfg)),
        ("error", Json::Null),
        ("summary", Json::Null),
    ]);
    std::fs::write(format!("{dir}/job-5.json"), manifest.to_string()).unwrap();

    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());
    let status = client.wait(5, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");
    assert_eq!(final_line_from_status(&status).unwrap(), direct_final_line(&cfg));
    daemon.stop();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// (c) hostile input never takes the daemon down
// ---------------------------------------------------------------------

#[test]
fn malformed_requests_get_4xx_and_daemon_keeps_serving() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let addr = daemon.addr();
    let client = Client::new(&addr);

    let barrage: &[&[u8]] = &[
        b"NOT-HTTP-AT-ALL",
        b"GET / HTTP/9.9\r\n\r\n",
        b"GET\r\n\r\n",
        b"GET / HTTP/1.1\r\nthis header has no colon\r\n\r\n",
        b"POST /v1/jobs HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
        b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
        b"POST /v1/jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        // Body shorter than Content-Length (we half-close so the server
        // sees EOF instead of hanging on read_exact).
        b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"co",
        // Well-formed HTTP, hostile JSON.
        b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 7\r\n\r\nnotjson",
        b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 2\r\n\r\n[[",
        b"GET /v1/jobs/99999 HTTP/1.1\r\n\r\n",
        b"GET /v1/jobs/banana/events HTTP/1.1\r\n\r\n",
        b"PUT /v1/healthz HTTP/1.1\r\n\r\n",
        b"POST /totally/elsewhere HTTP/1.1\r\n\r\n",
    ];
    for (i, garbage) in barrage.iter().enumerate() {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(garbage).unwrap();
        let _ = stream.shutdown(std::net::Shutdown::Write);
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        let mut reply = String::new();
        let _ = stream.read_to_string(&mut reply);
        if !reply.is_empty() {
            assert!(
                reply.starts_with("HTTP/1.1 4") || reply.starts_with("HTTP/1.1 5"),
                "barrage #{i}: expected an error status, got: {reply}"
            );
            assert!(
                reply.contains("\"error\""),
                "barrage #{i}: error body must be JSON: {reply}"
            );
        }
        // The daemon is still alive and serving after every volley.
        let health = client.healthz().unwrap();
        assert_eq!(
            health.get("status").unwrap().as_str(),
            Some("ok"),
            "daemon died after barrage #{i}"
        );
    }

    // A nesting bomb inside a well-formed request: 400, not a stack
    // overflow (the json parser's bounded recursion, end to end).
    let bomb_body = "[".repeat(10_000);
    let mut req = format!(
        "POST /v1/jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        bomb_body.len()
    )
    .into_bytes();
    req.extend(bomb_body.into_bytes());
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&req).unwrap();
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut reply = String::new();
    let _ = stream.read_to_string(&mut reply);
    assert!(reply.starts_with("HTTP/1.1 400"), "{reply}");

    // And real work still runs to completion afterwards.
    let id = client.submit(&mock_cfg(3, 1)).unwrap();
    let status = client.wait(id, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"));
    daemon.stop();
}

// ---------------------------------------------------------------------
// GET /v1/metrics round-trips live daemon telemetry
// ---------------------------------------------------------------------

#[test]
fn metrics_endpoint_round_trips_live_counters() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let client = Client::new(&daemon.addr());

    let cfg = native_cfg(5, 2);
    let id = client.submit(&cfg).unwrap();
    let status = client.wait(id, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");

    let m = client.metrics().unwrap();
    assert_eq!(m.get("format").unwrap().as_str(), Some("dpquant-metrics"));
    assert_eq!(m.get("version").unwrap().as_f64(), Some(1.0));
    assert!(m.get("uptime_seconds").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(m.get("workers").unwrap().as_usize(), Some(1));
    assert_eq!(m.get("queue_depth").unwrap().as_usize(), Some(0));
    assert_eq!(m.get("jobs").unwrap().get("done").unwrap().as_usize(), Some(1));

    // The finished job's ε spend is reported under its id, equal to the
    // summary's final_epsilon (same f64 through the same formatter).
    let eps = m.get("per_job_epsilon").unwrap().get("1").unwrap().as_f64().unwrap();
    let summary_eps = status
        .get("summary")
        .unwrap()
        .get("final_epsilon")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(eps, summary_eps);

    // The registry snapshot carries live pool + HTTP telemetry. The
    // registry is process-global (other tests in this binary may have
    // bumped it too), so assert presence and lower bounds, not exact
    // values.
    let reg = m.get("metrics").unwrap();
    let counters = reg.get("counters").unwrap();
    assert!(counters.get("pool.jobs_completed").unwrap().as_f64().unwrap() >= 1.0);
    assert!(counters.get("http.requests").unwrap().as_f64().unwrap() >= 1.0);
    let hists = reg.get("histograms").unwrap();
    assert!(hists.get("pool.busy_ns").is_some());
    assert!(hists.get("pool.queue_wait_ns").is_some());
    assert!(hists.get("http.request_ns").is_some());

    // Serving metrics is pure observation: the job's final metrics line
    // still diffs byte-identical against a direct run.
    assert_eq!(final_line_from_status(&status).unwrap(), direct_final_line(&cfg));
    daemon.stop();
}

// ---------------------------------------------------------------------
// Prometheus exposition + audit trail over the wire
// ---------------------------------------------------------------------

#[test]
fn prometheus_metrics_are_served_as_text_over_the_wire() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let client = Client::new(&daemon.addr());
    let id = client.submit(&mock_cfg(2, 1)).unwrap();
    client.wait(id, WAIT, POLL).unwrap();

    // Raw bytes, not JSON: the exposition must parse as plain
    // Prometheus text with at least the HTTP counter this very scrape
    // increments.
    let (status, body) =
        dpquant::serve::http::http_call_raw(&daemon.addr(), "GET", "/v1/metrics?format=prometheus", None)
            .unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("# TYPE"), "{text}");
    assert!(text.contains("http_requests"), "{text}");
    assert!(json::parse(&text).is_err(), "exposition must not be JSON");

    // An unknown format is a clean 400.
    let (status, _) =
        dpquant::serve::http::http_call_raw(&daemon.addr(), "GET", "/v1/metrics?format=xml", None)
            .unwrap();
    assert_eq!(status, 400);
    daemon.stop();
}

#[test]
fn audit_endpoint_serves_the_on_disk_trail_byte_exact() {
    use dpquant::obs::audit;

    let dir = temp_state_dir("audit");
    std::fs::create_dir_all(&dir).unwrap();
    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());

    let id = client.submit(&native_cfg(13, 2)).unwrap();
    let status = client.wait(id, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");

    // The wire body is the on-disk audit file, byte for byte.
    let wire = client.audit(id).unwrap();
    let disk = std::fs::read_to_string(format!("{dir}/job-{id}.audit.jsonl")).unwrap();
    assert!(!wire.is_empty());
    assert_eq!(wire, disk, "GET /v1/jobs/{id}/audit must serve the file verbatim");

    // And the served trail replays to the job's own reported ε, bitwise.
    let replay = audit::replay(&format!("{dir}/job-{id}.audit.jsonl")).unwrap();
    let summary_eps = status
        .get("summary")
        .unwrap()
        .get("final_epsilon")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(
        replay.final_epsilon.to_bits(),
        summary_eps.to_bits(),
        "replayed ε {} != job summary ε {summary_eps}",
        replay.final_epsilon
    );
    daemon.stop();
    std::fs::remove_dir_all(&dir).ok();

    // Without a --state-dir there is no trail: a distinct 404 that
    // names the cause.
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let client = Client::new(&daemon.addr());
    let id = client.submit(&mock_cfg(4, 1)).unwrap();
    client.wait(id, WAIT, POLL).unwrap();
    let err = client.audit(id).unwrap_err().to_string();
    assert!(err.contains("404"), "{err}");
    assert!(err.contains("no audit log"), "{err}");
    daemon.stop();
}

// ---------------------------------------------------------------------
// Cancel + events over the full stack
// ---------------------------------------------------------------------

#[test]
fn cancel_and_events_over_the_wire() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let client = Client::new(&daemon.addr());

    // A job far too long to finish: cancel stops it at an epoch
    // boundary.
    let long = client.submit(&mock_cfg(0, 100_000)).unwrap();
    // Wait until it has made observable progress (>= 1 epoch event).
    let mut made_progress = false;
    for _ in 0..2500 {
        let ev = client.events(long).unwrap();
        if ev.get("total").unwrap().as_usize().unwrap_or(0) >= 1 {
            made_progress = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    assert!(made_progress, "job produced no epoch events");
    client.cancel(long).unwrap();
    let status = client.wait(long, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("cancelled"));

    // Events carry epoch telemetry with consecutive sequence numbers.
    let ev = client.events(long).unwrap();
    let events = ev.get("events").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    for (i, e) in events.iter().enumerate() {
        let base = ev.get("dropped").unwrap().as_usize().unwrap();
        assert_eq!(e.get("seq").unwrap().as_usize(), Some(base + i));
        assert!(e.get("val_accuracy").unwrap().as_f64().is_some());
    }

    // Cancelling again is a clean 409, and a fresh job still runs.
    assert!(client.cancel(long).is_err());
    let id = client.submit(&mock_cfg(1, 2)).unwrap();
    let status = client.wait(id, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"));
    daemon.stop();
}
