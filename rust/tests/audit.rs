//! Tier-1 audit-trail tests: the acceptance contract of the
//! `dpquant-audit` v1 stream (DESIGN.md §17).
//!
//! (a) **Determinism**: two `--no-timing` audited runs of the same
//!     config produce byte-identical audit files.
//! (b) **Pure observation**: an audited run's final metrics line and
//!     final weight bits are identical to an unaudited run's — the
//!     audit trail can never perturb training.
//! (c) **Replay**: a real run's audit file passes `audit check` and
//!     `audit replay`, and the replayed ε is bitwise equal to the
//!     session's own final ε.
//! (d) **Golden replay**: an audit file carrying the
//!     `tests/privacy_golden.rs` composition (training q = 1/16,
//!     σ = 0.6, 64 steps + 3 analysis probes at q = 1/32, σ = 0.5)
//!     replays to the Python-pinned ε at δ = 1e-5.
//! (e) **Rejection**: malformed or doctored files fail with 1-based
//!     line numbers.

use dpquant::backend;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{AuditEpoch, NullSink, TrainSession};
use dpquant::data;
use dpquant::obs::{audit, AuditSink, AuditWriter};
use dpquant::privacy::{Mechanism, RdpAccountant, StepRecord};

/// The fast real-training config the obs/serve tests also use.
fn cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "logreg".into(),
        backend: "native".into(),
        dataset_size: 192,
        val_size: 64,
        batch_size: 16,
        physical_batch: 64,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dpquant_audit_it_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Run `cfg` to completion, optionally auditing to `audit_path` with
/// timing off — the same wiring `dpquant train --audit-out PATH
/// --no-timing` uses. Returns the final metrics line, every final
/// weight bit, and the session's final ε.
fn run(cfg: &TrainConfig, audit_path: Option<&str>) -> (String, Vec<Vec<u32>>, f64) {
    let (train_ds, val_ds) =
        data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed).unwrap();
    let exec =
        backend::open_sweep_executor(cfg, train_ds.example_numel, train_ds.n_classes).unwrap();
    let mut session = TrainSession::builder(cfg.clone()).build(exec.as_ref(), &train_ds).unwrap();
    let writer = audit_path.map(|p| {
        let w = AuditWriter::create(p, false).unwrap();
        w.begin_run(session.config(), train_ds.len(), session.accountant_history());
        w
    });
    let mut sink = writer.as_ref().map(AuditSink::new);
    while !session.is_finished() {
        match &mut sink {
            Some(s) => session.step_epoch(exec.as_ref(), &train_ds, &val_ds, s).unwrap(),
            None => session.step_epoch(exec.as_ref(), &train_ds, &val_ds, &mut NullSink).unwrap(),
        };
    }
    if let Some(w) = writer.as_ref() {
        w.finish().unwrap();
    }
    let bits = session
        .weights()
        .iter()
        .map(|t| t.iter().map(|x| x.to_bits()).collect())
        .collect();
    let record = session.record();
    (record.final_line(), bits, record.final_epsilon)
}

// ---------------------------------------------------------------------
// (a) byte determinism, (b) pure observation
// ---------------------------------------------------------------------

#[test]
fn no_timing_audited_runs_are_byte_identical() {
    let (pa, pb) = (tmp("det_a"), tmp("det_b"));
    let c = cfg(5, 2);
    run(&c, Some(&pa));
    run(&c, Some(&pb));
    let (a, b) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    assert!(!a.is_empty());
    assert_eq!(a, b, "--no-timing audit files of identical runs must diff clean");
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

#[test]
fn audited_and_unaudited_runs_produce_identical_outputs() {
    let path = tmp("inert");
    let c = cfg(17, 2);
    let (line_audited, bits_audited, _) = run(&c, Some(&path));
    let (line_plain, bits_plain, _) = run(&c, None);
    assert_eq!(
        line_audited, line_plain,
        "the final metrics line must not move when auditing is on"
    );
    assert_eq!(
        bits_audited, bits_plain,
        "final weights must be bit-identical with auditing on or off"
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// (c) a real run's trail checks and replays bitwise
// ---------------------------------------------------------------------

#[test]
fn real_run_audit_checks_and_replays_to_the_sessions_epsilon() {
    let path = tmp("replay");
    let c = cfg(3, 3);
    let (_, _, final_epsilon) = run(&c, Some(&path));

    let stats = audit::check(&path).unwrap();
    assert_eq!(stats.epochs, 3);
    assert!(stats.records >= 3, "{stats:?}");
    // The dpquant scheduler (default, analysis_interval 2) probes on
    // epochs 0 and 2 of a 3-epoch run.
    assert!(stats.analysis_steps > 0, "{stats:?}");
    assert!(!stats.truncated);

    let replay = audit::replay(&path).unwrap();
    assert_eq!(replay.epochs, 3);
    assert_eq!(
        replay.final_epsilon.to_bits(),
        final_epsilon.to_bits(),
        "replayed ε {} != session ε {}",
        replay.final_epsilon,
        final_epsilon
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// (d) golden replay against the Python-pinned composition
// ---------------------------------------------------------------------

/// An epoch record whose (ε, α) really is the composition of `delta`
/// on top of `acc` — the shape the session emits.
fn live_epoch(
    acc: &mut RdpAccountant,
    epoch: usize,
    delta: Vec<StepRecord>,
    at_delta: f64,
) -> AuditEpoch {
    for r in &delta {
        acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
    }
    let (epsilon, alpha) = acc.epsilon(at_delta);
    let steps = delta
        .iter()
        .filter(|r| r.mechanism == Mechanism::Training)
        .map(|r| r.steps)
        .sum();
    AuditEpoch {
        epoch,
        noise_multiplier: 0.6,
        sample_rate: 0.0625,
        clip_norm: 1.0,
        clip_scale: 1.0,
        lr_scales: None,
        mask: vec![0],
        draw_probs: vec![0.5, 0.5],
        accounting: delta,
        steps,
        epsilon,
        alpha,
        analysis_seconds: 0.0,
        truncated: false,
    }
}

fn train_block(steps: u64) -> StepRecord {
    StepRecord {
        mechanism: Mechanism::Training,
        sample_rate: 0.0625,
        noise_multiplier: 0.6,
        steps,
    }
}

fn analysis_block(steps: u64) -> StepRecord {
    StepRecord {
        mechanism: Mechanism::Analysis,
        sample_rate: 0.03125,
        noise_multiplier: 0.5,
        steps,
    }
}

#[test]
fn replay_reproduces_the_python_pinned_golden_epsilon() {
    // The tests/privacy_golden.rs composition, split across two audited
    // epochs: training (q = 1/16, σ = 0.6, 64 steps) + 3 analysis
    // probes (q = 1/32, σ = 0.5) at δ = 1e-5. Reference ε from the
    // independent Python port: 13.571260089202578.
    const GOLDEN_EPS: f64 = 13.571260089202578;
    let delta = 1e-5;
    let path = tmp("golden");
    let w = AuditWriter::create(&path, false).unwrap();
    let c = TrainConfig {
        epochs: 2,
        batch_size: 16,
        dataset_size: 256,
        noise_multiplier: 0.6,
        delta,
        ..TrainConfig::default()
    };
    w.begin_run(&c, 256, &[]);
    let mut acc = RdpAccountant::new();
    w.epoch(&live_epoch(
        &mut acc,
        0,
        vec![analysis_block(1), train_block(32)],
        delta,
    ));
    w.epoch(&live_epoch(
        &mut acc,
        1,
        vec![analysis_block(2), train_block(32)],
        delta,
    ));
    w.finish().unwrap();

    let replay = audit::replay(&path).unwrap();
    assert_eq!(replay.epochs, 2);
    // Bitwise against the live accountant that wrote the file...
    assert_eq!(replay.final_epsilon.to_bits(), acc.epsilon(delta).0.to_bits());
    // ...and pinned (1e-6 relative, the privacy_golden.rs tolerance)
    // against the independent Python reference value.
    let rel = (replay.final_epsilon - GOLDEN_EPS).abs() / GOLDEN_EPS;
    assert!(
        rel < 1e-6,
        "replayed ε {} drifted from the Python golden {GOLDEN_EPS} (rel {rel:.3e})",
        replay.final_epsilon
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// (e) malformed and doctored files are rejected with line numbers
// ---------------------------------------------------------------------

#[test]
fn malformed_and_doctored_audits_are_rejected_with_line_numbers() {
    let path = tmp("reject");

    // Wrong header format tag.
    std::fs::write(&path, "{\"format\":\"nope\",\"version\":1}\n").unwrap();
    let e = audit::check(&path).unwrap_err().to_string();
    assert!(e.contains("line 1"), "{e}");

    // A real run, then flip one bit of the last recorded ε: check()
    // (structural) still passes, replay() names the line.
    let c = cfg(9, 2);
    run(&c, Some(&path));
    let text = std::fs::read_to_string(&path).unwrap();
    let mut lines: Vec<String> = text.lines().map(String::from).collect();
    let last = lines.last().unwrap().clone();
    let j = dpquant::util::json::parse(&last).unwrap();
    let eps_hex = j.get("epsilon").unwrap().as_str().unwrap().to_string();
    let bits = u64::from_str_radix(&eps_hex, 16).unwrap() ^ 1;
    *lines.last_mut().unwrap() = last.replace(&eps_hex, &format!("{bits:016x}"));
    std::fs::write(&path, lines.join("\n") + "\n").unwrap();
    assert!(audit::check(&path).is_ok());
    let e = audit::replay(&path).unwrap_err().to_string();
    assert!(e.contains(&format!("audit line {}", lines.len())), "{e}");
    assert!(e.contains("replayed epsilon"), "{e}");

    // Truncating a line mid-record is caught as invalid JSON with the
    // right line number.
    let torn: String = text.lines().take(2).collect::<Vec<_>>().join("\n")
        + "\n{\"kind\":\"epoch\",\"epo\n";
    std::fs::write(&path, torn).unwrap();
    let e = audit::check(&path).unwrap_err().to_string();
    assert!(e.contains("audit line 3"), "{e}");
    std::fs::remove_file(&path).ok();
}
