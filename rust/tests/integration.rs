//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These require `make artifacts` (they are skipped, loudly, when the
//! artifact directory is missing — CI without Python can still run the
//! pure-Rust suite). The same coordinator loop is exercised **without
//! artifacts** by `tests/native_backend.rs` via the default
//! `--backend native` engine, so `cargo test -q` always covers the full
//! train path end to end.

use dpquant::config::{OptimizerKind, TrainConfig};
use dpquant::coordinator::{train, StepExecutor, TrainerOptions};
use dpquant::data;
use dpquant::privacy::Mechanism;
use dpquant::runtime::Runtime;

fn open_runtime() -> Option<Runtime> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!(
            "SKIP: artifacts/ missing — run `make artifacts` (the native-backend tests \
             cover the offline path)"
        );
        return None;
    }
    // Artifacts alone are not enough: executing them needs a real PJRT
    // backend in place of the bundled `xla` stub (see rust/src/xla.rs).
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!(
                "SKIP: artifacts present but runtime unavailable (use `--backend native` \
                 for artifact-free runs): {e:#}"
            );
            None
        }
    }
}

#[test]
fn unavailable_runtime_skips_loudly_instead_of_failing() {
    // The PJRT tests below must *skip* (return early), never fail, unless
    // both artifacts and a working backend exist. And a missing artifact
    // directory must surface a clean error — not a panic.
    if open_runtime().is_none() {
        let missing = format!("{}/no-such-artifacts", env!("CARGO_MANIFEST_DIR"));
        let err = Runtime::open(&missing).unwrap_err();
        assert!(format!("{err:#}").contains("manifest.json"));
    }
}

#[test]
fn manifest_and_all_graphs_listed() {
    let Some(rt) = open_runtime() else { return };
    assert!(rt.manifest.graphs.len() >= 8);
    for (tag, g) in &rt.manifest.graphs {
        assert_eq!(g.quant_layer_names.len(), g.n_quant_layers, "{tag}");
        assert!(g.batch > 0 && g.total_params() > 0, "{tag}");
    }
}

#[test]
fn train_step_executes_and_respects_mask_semantics() {
    let Some(rt) = open_runtime() else { return };
    let g = rt.load("miniconvnet_gtsrb_luq4").unwrap();
    let b = g.batch();
    let ds = data::generate("gtsrb", b, 1).unwrap();
    let batch = &data::eval_batches(&ds, b)[0];

    // Full-precision step.
    let fp_mask = vec![0f32; g.info.n_quant_layers];
    let out = g
        .train_step(&g.init_weights, &batch.x, &batch.y, &batch.mask, &fp_mask, 1.0)
        .unwrap();
    assert_eq!(out.grad_sums.len(), g.info.params.len());
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
    assert!(out.raw_norm_max >= 0.0);

    // Clip bound: ‖Σ clipped‖ ≤ B·C.
    let total: f64 = out
        .grad_sums
        .iter()
        .flat_map(|gs| gs.iter())
        .map(|&x| x as f64 * x as f64)
        .sum();
    assert!(total.sqrt() <= b as f64 * g.info.clip_norm + 1e-3);

    // Quantized step differs from fp but still bounded.
    let q_mask = vec![1f32; g.info.n_quant_layers];
    let qout = g
        .train_step(&g.init_weights, &batch.x, &batch.y, &batch.mask, &q_mask, 1.0)
        .unwrap();
    let diff: f64 = out
        .grad_sums
        .iter()
        .zip(&qout.grad_sums)
        .flat_map(|(a, c)| a.iter().zip(c.iter()))
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .sum();
    assert!(diff > 0.0, "quantization must perturb gradients");

    // Determinism: same inputs + seed → identical outputs.
    let out2 = g
        .train_step(&g.init_weights, &batch.x, &batch.y, &batch.mask, &fp_mask, 1.0)
        .unwrap();
    assert_eq!(out.grad_sums, out2.grad_sums);
    assert_eq!(out.loss_sum, out2.loss_sum);
}

#[test]
fn eval_matches_manual_count_bounds() {
    let Some(rt) = open_runtime() else { return };
    let g = rt.load("miniconvnet_cifar_luq4").unwrap();
    let b = g.batch();
    let ds = data::generate("cifar", b, 2).unwrap();
    let batch = &data::eval_batches(&ds, b)[0];
    let out = g
        .eval_step(&g.init_weights, &batch.x, &batch.y, &batch.mask)
        .unwrap();
    assert!(out.correct_sum >= 0.0 && out.correct_sum <= b as f32);
    assert!(out.loss_sum > 0.0);

    // Half-masked batch counts at most the full batch.
    let mut half = batch.mask.clone();
    for m in half.iter_mut().skip(b / 2) {
        *m = 0.0;
    }
    let out_half = g
        .eval_step(&g.init_weights, &batch.x, &batch.y, &half)
        .unwrap();
    assert!(out_half.correct_sum <= out.correct_sum + 1e-3);
    assert!(out_half.loss_sum <= out.loss_sum + 1e-3);
}

#[test]
fn short_training_reduces_loss_and_accounts() {
    let Some(rt) = open_runtime() else { return };
    let g = rt.load("miniconvnet_gtsrb_luq4").unwrap();
    let cfg = TrainConfig {
        epochs: 3,
        dataset_size: 512,
        val_size: 128,
        batch_size: 64,
        noise_multiplier: 0.6,
        lr: 0.5,
        scheduler: "dpquant".into(),
        quant_fraction: 0.5,
        ..TrainConfig::default()
    };
    let full = data::generate("gtsrb", cfg.dataset_size + cfg.val_size, 5).unwrap();
    let (tr, va) = full.split(cfg.val_size);
    let res = train(&g, &cfg, &tr, &va, &TrainerOptions::default()).unwrap();
    assert_eq!(res.record.epochs.len(), 3);
    let first = res.record.epochs.first().unwrap().train_loss;
    let last = res.record.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(res.record.final_epsilon > 0.0);
    assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 2); // epochs 0, 2
    // Every epoch quantized exactly k = 4 of 8 layers.
    for e in &res.record.epochs {
        assert_eq!(e.quantized_layers.len(), 4);
    }
}

#[test]
fn transformer_dp_adamw_runs() {
    let Some(rt) = open_runtime() else { return };
    let g = rt.load("tinytransformer_snli_luq4").unwrap();
    assert_eq!(g.info.example_dtype, "int32");
    let cfg = TrainConfig {
        model: "tinytransformer".into(),
        dataset: "snli".into(),
        optimizer: OptimizerKind::AdamW,
        lr: 0.01,
        epochs: 2,
        dataset_size: 512,
        val_size: 128,
        batch_size: 64,
        scheduler: "pls".into(),
        quant_fraction: 0.5,
        ..TrainConfig::default()
    };
    let full = data::generate("snli", cfg.dataset_size + cfg.val_size, 6).unwrap();
    let (tr, va) = full.split(cfg.val_size);
    let res = train(&g, &cfg, &tr, &va, &TrainerOptions::default()).unwrap();
    assert!(res.record.final_accuracy > 0.15); // 3-way task, should be ≥ near-chance
    assert!(res.record.final_epsilon > 0.0);
}

#[test]
fn quantizer_variants_load_and_step() {
    let Some(rt) = open_runtime() else { return };
    for tag in ["miniresnet_cifar_fp8", "miniresnet_cifar_uniform4"] {
        let g = rt.load(tag).unwrap();
        let b = g.batch();
        let ds = data::generate("cifar", b, 3).unwrap();
        let batch = &data::eval_batches(&ds, b)[0];
        let mask = vec![1f32; g.info.n_quant_layers];
        let out = g
            .train_step(&g.init_weights, &batch.x, &batch.y, &batch.mask, &mask, 0.0)
            .unwrap();
        assert!(out.loss_sum.is_finite(), "{tag}");
    }
}
