//! Property tests for the `quant/` kernels **on the native backend's
//! live path**: quantization is invoked exactly as the hot loop does —
//! through `backend::quantize_masked_weights` over the model's actual
//! parameter tensors (conv `[cout][cin][3][3]`, dense `[out][in]`) —
//! not over standalone synthetic vectors.
//!
//! Checked properties: quantize→dequantize round-trip error bounds,
//! mask/bias isolation, seeded determinism, and monotonicity of the
//! (expected) quantized value in the input value.

use dpquant::backend::{quantize_masked_weights, NativeExecutor};
use dpquant::config::TrainConfig;
use dpquant::coordinator::StepExecutor;
use dpquant::quant;

fn cnn_exec(quantizer: &str) -> NativeExecutor {
    let cfg = TrainConfig {
        quantizer: quantizer.into(),
        seed: 21,
        ..TrainConfig::default()
    };
    // Default model "miniconvnet" over the 16x16x3 image shape.
    NativeExecutor::from_config(&cfg, 16 * 16 * 3, 10).unwrap()
}

#[test]
fn roundtrip_error_bounds_on_live_tensors() {
    for name in ["luq4", "uniform4", "fp8"] {
        let exec = cnn_exec(name);
        let model = exec.model();
        let w = exec.initial_weights();
        let nl = exec.n_quant_layers();
        let mask = vec![1f32; nl];
        let q = quant::by_name(name).unwrap();
        let qw = quantize_masked_weights(model, &w, &mask, q.as_ref(), 0.5);
        for l in 0..nl {
            let wi = model.weight_index(l);
            let max_abs = w[wi].iter().fold(0f32, |m, &v| m.max(v.abs()));
            for (i, (&a, &b)) in w[wi].iter().zip(&qw[wi]).enumerate() {
                let e = (a - b).abs();
                match name {
                    // LUQ-FP4: err ≤ octave gap ≤ max/2 (underflow band
                    // err ≤ α = max/128 is far smaller).
                    "luq4" => assert!(
                        e <= max_abs / 2.0 + 1e-6,
                        "{name} layer {l} elem {i}: |{a} - {b}| > max/2"
                    ),
                    // Uniform INT4: stochastic round to an adjacent grid
                    // point — within one step.
                    "uniform4" => {
                        let step = 2.0 * max_abs / 15.0;
                        assert!(
                            e <= step + 1e-6,
                            "{name} layer {l} elem {i}: |{a} - {b}| > step {step}"
                        );
                    }
                    // FP8-E5M2: ≤ 2^-3 relative in the normal range.
                    _ => {
                        if a.abs() >= 6.103515625e-5 {
                            assert!(
                                e <= 0.125 * a.abs() + 1e-6,
                                "{name} layer {l} elem {i}: {a} -> {b}"
                            );
                        }
                    }
                }
            }
            // Scale containment: quantization cannot blow the tensor's
            // ∞-norm past one grid step.
            let qmax = qw[wi].iter().fold(0f32, |m, &v| m.max(v.abs()));
            assert!(
                qmax <= max_abs * (1.0 + 2.0 / 15.0) + 1e-6,
                "{name} layer {l}: ∞-norm grew {max_abs} -> {qmax}"
            );
        }
    }
}

#[test]
fn only_masked_weight_tensors_change_and_biases_stay_fp32() {
    let exec = cnn_exec("luq4");
    let model = exec.model();
    let w = exec.initial_weights();
    let nl = exec.n_quant_layers();
    let mut mask = vec![0f32; nl];
    mask[1] = 1.0;
    mask[3] = 1.0;
    let q = quant::by_name("luq4").unwrap();
    let qw = quantize_masked_weights(model, &w, &mask, q.as_ref(), 1.0);
    let weight_idx: Vec<usize> = (0..nl).map(|l| model.weight_index(l)).collect();
    for l in 0..nl {
        let wi = weight_idx[l];
        if mask[l] > 0.0 {
            assert_ne!(w[wi], qw[wi], "masked layer {l} must be quantized");
        } else {
            assert_eq!(w[wi], qw[wi], "unmasked layer {l} must be untouched");
        }
    }
    for (ti, t) in qw.iter().enumerate() {
        if !weight_idx.contains(&ti) {
            assert_eq!(&w[ti], t, "param tensor {ti} is a bias and stays fp32");
        }
    }
}

#[test]
fn weight_quantization_deterministic_per_seed() {
    let exec = cnn_exec("luq4");
    let model = exec.model();
    let w = exec.initial_weights();
    let mask = vec![1f32; exec.n_quant_layers()];
    let q = quant::by_name("luq4").unwrap();
    let a = quantize_masked_weights(model, &w, &mask, q.as_ref(), 2.0);
    let b = quantize_masked_weights(model, &w, &mask, q.as_ref(), 2.0);
    assert_eq!(a, b, "same step seed must reproduce the same rounding");
    let c = quantize_masked_weights(model, &w, &mask, q.as_ref(), 3.0);
    assert_ne!(a, c, "a new step seed must re-roll stochastic rounding");
}

#[test]
fn fp8_quantization_is_monotone_on_live_tensors() {
    // fp8 is deterministic round-to-nearest: sorting a real dense weight
    // tensor then quantizing must preserve (non-strict) order.
    let exec = cnn_exec("fp8");
    let model = exec.model();
    let mut w = exec.initial_weights();
    let wi = model.weight_index(2); // the big dense head tensor
    w[wi].sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mask = vec![1f32; exec.n_quant_layers()];
    let q = quant::by_name("fp8").unwrap();
    let qw = quantize_masked_weights(model, &w, &mask, q.as_ref(), 0.0);
    for pair in qw[wi].windows(2) {
        assert!(pair[0] <= pair[1], "fp8 broke order: {} > {}", pair[0], pair[1]);
    }
}

#[test]
fn stochastic_quantizers_monotone_in_expectation_on_live_tensors() {
    for name in ["luq4", "uniform4"] {
        let exec = cnn_exec(name);
        let model = exec.model();
        let w = exec.initial_weights();
        let nl = exec.n_quant_layers();
        // Mask only layer 0 (the conv1 tensor, 216 elements) to keep the
        // trial loop cheap while still going through the live entry
        // point.
        let mut mask = vec![0f32; nl];
        mask[0] = 1.0;
        let wi = model.weight_index(0);
        let q = quant::by_name(name).unwrap();
        let trials = 400usize;
        let mut means = vec![0f64; w[wi].len()];
        for t in 0..trials {
            let qw = quantize_masked_weights(model, &w, &mask, q.as_ref(), t as f32 + 0.25);
            for (m, &v) in means.iter_mut().zip(&qw[wi]) {
                *m += v as f64;
            }
        }
        for m in means.iter_mut() {
            *m /= trials as f64;
        }
        let max_abs = w[wi].iter().fold(0f32, |m, &v| m.max(v.abs())) as f64;
        // Spread 12 probe elements across the sorted value range; any
        // well-separated pair must keep its order in expectation.
        let mut idx: Vec<usize> = (0..means.len()).collect();
        idx.sort_by(|&a, &b| w[wi][a].partial_cmp(&w[wi][b]).unwrap());
        let probes: Vec<usize> = (0..12)
            .map(|k| idx[k * (idx.len() - 1) / 11])
            .collect();
        for ai in 0..probes.len() {
            for bi in (ai + 1)..probes.len() {
                let (pa, pb) = (probes[ai], probes[bi]);
                let gap = (w[wi][pb] - w[wi][pa]) as f64;
                if gap > 0.15 * max_abs {
                    assert!(
                        means[pa] <= means[pb] + 0.1 * max_abs,
                        "{name}: E[q] broke order: x {} -> {}, E {} vs {}",
                        w[wi][pa],
                        w[wi][pb],
                        means[pa],
                        means[pb]
                    );
                }
            }
        }
        // Unbiasedness on the live tensor: E[q(w)] ≈ w elementwise.
        for (i, (&m, &v)) in means.iter().zip(&w[wi]).enumerate() {
            assert!(
                (m - v as f64).abs() < 0.08 * max_abs.max(0.05),
                "{name}: biased at elem {i}: E {m} vs x {v}"
            );
        }
    }
}
