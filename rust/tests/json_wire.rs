//! `util/json` as a **wire format** (satellite of the serve PR).
//!
//! Since the serving daemon, this parser reads bytes from the network,
//! not just files we wrote ourselves. These tests pin the hostile-input
//! contract: truncated documents, nesting bombs (bounded recursion — no
//! stack overflow), bad escapes, overflowing numbers, duplicate keys,
//! and the IEEE-754 hex-bits float convention the checkpoint formats
//! ride on. Note `parse` takes `&str`, so invalid UTF-8 is excluded at
//! the type level — the HTTP layer rejects non-UTF-8 bodies before
//! parsing.

use dpquant::util::json::{self, Json, MAX_DEPTH};

#[test]
fn truncated_documents_error_cleanly() {
    for doc in [
        "{",
        "}",
        "[",
        "[1,",
        "[1, 2",
        "{\"a\":",
        "{\"a\": 1,",
        "{\"a\"",
        "\"abc",
        "\"abc\\",
        "tru",
        "nul",
        "fals",
        "-",
        "1e",
        "\"\\u00",
        "",
        "   ",
        "{\"a\": 1} trailing",
        "[1] [2]",
    ] {
        assert!(json::parse(doc).is_err(), "must reject {doc:?}");
    }
}

#[test]
fn nesting_bombs_error_instead_of_overflowing_the_stack() {
    // 100k unclosed arrays: without bounded recursion this is a stack
    // overflow (an abort, not a catchable panic) — the bug class this
    // test exists to keep dead.
    let bomb = "[".repeat(100_000);
    let e = json::parse(&bomb).unwrap_err();
    assert!(e.contains("nesting"), "{e}");

    // Same through objects and mixed containers.
    let obj_bomb = "{\"k\":".repeat(100_000);
    let e = json::parse(&obj_bomb).unwrap_err();
    assert!(e.contains("nesting"), "{e}");
    let mixed = "[{\"k\":".repeat(50_000);
    let e = json::parse(&mixed).unwrap_err();
    assert!(e.contains("nesting"), "{e}");

    // A *closed* document right at the cap parses; one level deeper
    // does not. Only containers count: a scalar leaf at the bottom of
    // exactly MAX_DEPTH containers is still legal.
    let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
    assert!(json::parse(&ok).is_ok());
    let ok_scalar = format!("{}0{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
    assert!(json::parse(&ok_scalar).is_ok());
    let too_deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
    assert!(json::parse(&too_deep).is_err());
}

#[test]
fn bad_escapes_and_lone_surrogates_never_panic() {
    assert!(json::parse(r#""\q""#).is_err());
    assert!(json::parse(r#""\x41""#).is_err());
    assert!(json::parse(r#""\u12g4""#).is_err());
    assert!(json::parse(r#""\u""#).is_err());
    // A lone surrogate is not a scalar value; the parser substitutes
    // U+FFFD rather than panicking or fabricating invalid UTF-8.
    let v = json::parse(r#""\ud800""#).unwrap();
    assert_eq!(v.as_str().unwrap(), "\u{fffd}");
    // Escapes that ARE valid round-trip through our writer.
    let v = json::parse(r#""line\nbreak \"quoted\" tab\there A""#).unwrap();
    assert_eq!(v.as_str().unwrap(), "line\nbreak \"quoted\" tab\there A");
    let rewritten = v.to_string();
    assert_eq!(json::parse(&rewritten).unwrap(), v);
}

#[test]
fn numbers_that_overflow_f64_are_rejected() {
    for doc in ["1e999", "-1e999", "1e400", "123456789e999999"] {
        let e = json::parse(doc).unwrap_err();
        assert!(e.contains("overflow"), "{doc:?} -> {e}");
    }
    // The extremes that DO fit stay exact.
    assert_eq!(json::parse("1e308").unwrap().as_f64(), Some(1e308));
    assert_eq!(json::parse("-1e308").unwrap().as_f64(), Some(-1e308));
    // Underflow to zero is fine (it is a representable value).
    assert_eq!(json::parse("1e-999").unwrap().as_f64(), Some(0.0));
}

#[test]
fn duplicate_keys_resolve_last_wins() {
    let v = json::parse(r#"{"a": 1, "b": 0, "a": 2}"#).unwrap();
    assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
    assert_eq!(v.get("b").unwrap().as_f64(), Some(0.0));
    assert_eq!(v.as_obj().unwrap().len(), 2);
}

#[test]
fn hex_bits_float_convention_roundtrips_bit_exactly() {
    // The checkpoint formats ship every float as its IEEE-754 bit
    // pattern in a hex string. The wire must carry those strings
    // verbatim — including patterns for -0.0, subnormals, and NaN,
    // which decimal text could corrupt.
    let patterns: [u64; 7] = [
        0.0f64.to_bits(),
        (-0.0f64).to_bits(),
        1.5f64.to_bits(),
        f64::MIN_POSITIVE.to_bits(),
        4.9e-324f64.to_bits(), // smallest subnormal
        f64::NAN.to_bits(),
        0xdead_beef_cafe_f00d,
    ];
    for bits in patterns {
        let doc = Json::Str(format!("{bits:016x}")).to_string();
        let back = json::parse(&doc).unwrap();
        let recovered = u64::from_str_radix(back.as_str().unwrap(), 16).unwrap();
        assert_eq!(recovered, bits, "bit pattern {bits:016x} must survive the wire");
    }

    // The f32-blob convention (weights: concatenated 8-hex-char words).
    let weights: [f32; 5] = [0.0, -0.0, 1.0 / 3.0, f32::MIN_POSITIVE, -1.5e-40];
    let blob: String = weights.iter().map(|w| format!("{:08x}", w.to_bits())).collect();
    let doc = json::obj(vec![("w", Json::Str(blob.clone()))]).to_string();
    let back = json::parse(&doc).unwrap();
    let blob_back = back.get("w").unwrap().as_str().unwrap();
    assert_eq!(blob_back, blob);
    for (i, w) in weights.iter().enumerate() {
        let bits = u32::from_str_radix(&blob_back[i * 8..i * 8 + 8], 16).unwrap();
        assert_eq!(bits, w.to_bits());
    }
}

#[test]
fn plain_numbers_roundtrip_exactly_through_text() {
    // The serve API sends summaries as plain JSON numbers; Rust's
    // shortest-round-trip float formatting plus our parser must be
    // lossless (this is what makes `job status` lines byte-identical
    // to `train`'s).
    for x in [
        0.1 + 0.2,
        1.0 / 3.0,
        -7.77,
        1e-12,
        123456789.123456,
        f64::MAX,
        -f64::MIN_POSITIVE,
        42.0,
    ] {
        let doc = Json::Num(x).to_string();
        let back = json::parse(&doc).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), x.to_bits(), "{x} reread as {back}");
    }
    // The one documented exception: -0.0 serializes through the integer
    // path as "0" and loses its sign — which is exactly why the
    // checkpoint formats carry floats as hex bit patterns instead.
    assert_eq!(Json::Num(-0.0).to_string(), "0");
}

#[test]
fn large_flat_payloads_parse_fine() {
    // Bounded DEPTH must not mean bounded SIZE: wide documents are
    // legal wire traffic (a sweep report, a long event ring).
    let wide = format!(
        "[{}]",
        (0..20_000).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    );
    let v = json::parse(&wide).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 20_000);
    assert_eq!(v.as_arr().unwrap()[19_999].as_usize(), Some(19_999));

    let long_string = "x".repeat(300_000);
    let doc = Json::Str(long_string.clone()).to_string();
    assert_eq!(json::parse(&doc).unwrap().as_str().unwrap().len(), 300_000);

    // Many sibling keys, each shallow.
    let many = format!(
        "{{{}}}",
        (0..5_000)
            .map(|i| format!("\"k{i}\": {i}"))
            .collect::<Vec<_>>()
            .join(",")
    );
    let v = json::parse(&many).unwrap();
    assert_eq!(v.as_obj().unwrap().len(), 5_000);
}

#[test]
fn scalar_roots_and_unicode_bodies() {
    assert_eq!(json::parse("3").unwrap(), Json::Num(3.0));
    assert_eq!(json::parse("true").unwrap(), Json::Bool(true));
    assert_eq!(json::parse("null").unwrap(), Json::Null);
    assert_eq!(json::parse("\"s\"").unwrap().as_str(), Some("s"));
    // Multi-byte UTF-8 passes through unharmed (2-, 3-, 4-byte forms).
    let v = json::parse("\"é ⚡ 🚀 end\"").unwrap();
    assert_eq!(v.as_str().unwrap(), "é ⚡ 🚀 end");
    let rewritten = v.to_string();
    assert_eq!(json::parse(&rewritten).unwrap(), v);
}
