//! Parity suite for the cache-blocked kernels (DESIGN.md §13): the
//! blocked matmul/conv/dense paths vs their retained naive references
//! on ~100 random shapes (remainder tiles included), the fused
//! quantize-epilogue vs the separate whole-tensor passes for every
//! quantizer, and whole-run training determinism through the fused
//! executor.
//!
//! The determinism contract being pinned here:
//! * `matmul_blocked`, `conv3x3_forward`, `conv3x3_backward` (from
//!   zeroed grads), `dense_forward` and `dense_backward` are
//!   **bit-exact** against the naive references — blocking reorders
//!   loops, not the per-element FLOP chains.
//! * `conv3x3_backward` accumulating into *pre-filled* `gw` is
//!   tolerance-pinned (≤1e-5 relative): the blocked path sums its
//!   contribution in packed scratch before adding it on.
//! * The fused weight-prologue/grad-epilogue path produces the exact
//!   tensors the old separate `quantize_masked_weights` + grad-pass
//!   flow produced, including the RNG draw order.

use dpquant::backend::model::Model;
use dpquant::backend::{quantize_masked_weights, tensor, NativeExecutor, QuantEpilogue};
use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, StepExecutor, TrainerOptions};
use dpquant::data;
use dpquant::quant;
use dpquant::util::rng::Xoshiro256;

fn fill(rng: &mut Xoshiro256, buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = rng.next_f32() - 0.5;
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: elem {i}: {x} vs {y}"
        );
    }
}

// --- blocked GEMM vs naive ------------------------------------------------

#[test]
fn blocked_matmul_bit_exact_on_random_shapes() {
    let mut rng = Xoshiro256::seed_from_u64(11);
    // 60 random shapes spanning every remainder case: the micro-tile
    // (MR=4 x NR=8), the MC/NC macro tiles, and the KC panel boundary.
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (4, 8, 8),
        (5, 9, 7),
        (tensor::MC, 16, tensor::NC),
        (tensor::MC + 1, 16, tensor::NC + 1),
        (3, tensor::KC, 9),
        (3, tensor::KC + 5, 9),
        (tensor::MC - 1, tensor::KC - 1, tensor::NC - 1),
    ];
    for s in 0..52u64 {
        let mut srng = Xoshiro256::seed_from_u64(1000 + s);
        let m = 1 + srng.next_below(70) as usize;
        let k = 1 + srng.next_below(if s % 4 == 0 { 300 } else { 60 }) as usize;
        let n = 1 + srng.next_below(140) as usize;
        shapes.push((m, k, n));
    }
    for &(m, k, n) in &shapes {
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        fill(&mut rng, &mut a);
        fill(&mut rng, &mut b);
        // Real activations are sparse after relu — plant zeros so the
        // shared skip-zero branch is exercised in both paths.
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let mut naive = vec![0f32; m * n];
        let mut blocked = vec![0f32; m * n];
        tensor::matmul(&a, &b, m, k, n, &mut naive);
        tensor::matmul_blocked(&a, &b, m, k, n, &mut blocked);
        assert_eq!(
            bits(&naive),
            bits(&blocked),
            "matmul {m}x{k}x{n}: blocked must be bit-exact"
        );
    }
}

// --- blocked conv3x3 vs naive ---------------------------------------------

fn conv_shapes() -> Vec<(usize, usize, usize, usize)> {
    let mut shapes = vec![(1, 1, 1, 1), (2, 3, 1, 2), (16, 16, 8, 16), (8, 16, 3, 8)];
    for s in 0..16u64 {
        let mut srng = Xoshiro256::seed_from_u64(2000 + s);
        shapes.push((
            1 + srng.next_below(9) as usize,
            1 + srng.next_below(9) as usize,
            1 + srng.next_below(7) as usize,
            1 + srng.next_below(9) as usize,
        ));
    }
    shapes
}

#[test]
fn blocked_conv_forward_bit_exact() {
    let mut rng = Xoshiro256::seed_from_u64(12);
    for (h, wd, cin, cout) in conv_shapes() {
        let mut w = vec![0f32; cout * cin * 9];
        let mut b = vec![0f32; cout];
        let mut a = vec![0f32; h * wd * cin];
        fill(&mut rng, &mut w);
        fill(&mut rng, &mut b);
        fill(&mut rng, &mut a);
        let mut naive = vec![0f32; h * wd * cout];
        let mut blocked = vec![0f32; h * wd * cout];
        tensor::conv3x3_forward_ref(&w, &b, &a, &mut naive, h, wd, cin, cout);
        tensor::conv3x3_forward(&w, &b, &a, &mut blocked, h, wd, cin, cout);
        assert_eq!(
            bits(&naive),
            bits(&blocked),
            "conv3x3_forward {h}x{wd}x{cin}x{cout}: must be bit-exact"
        );
    }
}

#[test]
fn blocked_conv_backward_bit_exact_from_zeroed_grads() {
    let mut rng = Xoshiro256::seed_from_u64(13);
    for (h, wd, cin, cout) in conv_shapes() {
        let mut w = vec![0f32; cout * cin * 9];
        let mut a = vec![0f32; h * wd * cin];
        let mut dy = vec![0f32; h * wd * cout];
        fill(&mut rng, &mut w);
        fill(&mut rng, &mut a);
        fill(&mut rng, &mut dy);
        // Sparse dy exercises the shared skip-zero branch.
        for v in dy.iter_mut().step_by(4) {
            *v = 0.0;
        }
        for want_da in [true, false] {
            let mut gw_n = vec![0f32; w.len()];
            let mut gb_n = vec![0f32; cout];
            let mut da_n = vec![0f32; a.len()];
            let mut gw_b = vec![0f32; w.len()];
            let mut gb_b = vec![0f32; cout];
            let mut da_b = vec![0f32; a.len()];
            tensor::conv3x3_backward_ref(
                &w,
                &a,
                &dy,
                &mut gw_n,
                &mut gb_n,
                want_da.then_some(&mut da_n[..]),
                h,
                wd,
                cin,
                cout,
            );
            tensor::conv3x3_backward(
                &w,
                &a,
                &dy,
                &mut gw_b,
                &mut gb_b,
                want_da.then_some(&mut da_b[..]),
                h,
                wd,
                cin,
                cout,
            );
            let tag = format!("conv3x3_backward {h}x{wd}x{cin}x{cout} da={want_da}");
            assert_eq!(bits(&gw_n), bits(&gw_b), "{tag}: gw");
            assert_eq!(bits(&gb_n), bits(&gb_b), "{tag}: gb");
            assert_eq!(bits(&da_n), bits(&da_b), "{tag}: da");
        }
    }
}

#[test]
fn blocked_conv_backward_close_with_preaccumulated_grads() {
    // The executor always hands conv3x3_backward zeroed per-sample
    // grads (the bit-exact case above). Accumulating into pre-filled
    // gw is still supported but tolerance-pinned: the blocked kernel
    // sums its own contribution in packed scratch first.
    let mut rng = Xoshiro256::seed_from_u64(14);
    let (h, wd, cin, cout) = (7, 5, 3, 4);
    let mut w = vec![0f32; cout * cin * 9];
    let mut a = vec![0f32; h * wd * cin];
    let mut dy = vec![0f32; h * wd * cout];
    let mut pre = vec![0f32; w.len()];
    fill(&mut rng, &mut w);
    fill(&mut rng, &mut a);
    fill(&mut rng, &mut dy);
    fill(&mut rng, &mut pre);
    let mut gw_n = pre.clone();
    let mut gb_n = vec![0f32; cout];
    let mut gw_b = pre.clone();
    let mut gb_b = vec![0f32; cout];
    tensor::conv3x3_backward_ref(&w, &a, &dy, &mut gw_n, &mut gb_n, None, h, wd, cin, cout);
    tensor::conv3x3_backward(&w, &a, &dy, &mut gw_b, &mut gb_b, None, h, wd, cin, cout);
    assert_close(&gw_n, &gw_b, 1e-5, "conv3x3_backward pre-accumulated gw");
}

// --- blocked dense vs naive -----------------------------------------------

#[test]
fn blocked_dense_forward_and_backward_match_reference() {
    let mut rng = Xoshiro256::seed_from_u64(15);
    let mut shapes: Vec<(usize, usize)> = vec![(1, 1), (1024, 96), (33, 5), (256, 10)];
    for s in 0..16u64 {
        let mut srng = Xoshiro256::seed_from_u64(3000 + s);
        shapes.push((
            1 + srng.next_below(300) as usize,
            1 + srng.next_below(40) as usize,
        ));
    }
    for &(input, output) in &shapes {
        let mut w = vec![0f32; output * input];
        let mut b = vec![0f32; output];
        let mut a = vec![0f32; input];
        let mut dy = vec![0f32; output];
        fill(&mut rng, &mut w);
        fill(&mut rng, &mut b);
        fill(&mut rng, &mut a);
        fill(&mut rng, &mut dy);
        // Post-relu activations and sparse upstream grads both hit the
        // skip-zero branches.
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        for v in dy.iter_mut().step_by(2) {
            *v = 0.0;
        }
        let tag = format!("dense {input}->{output}");

        let mut out_n = vec![0f32; output];
        let mut out_b = vec![0f32; output];
        for bias in [Some(&b[..]), None] {
            tensor::dense_forward_ref(&w, bias, &a, &mut out_n);
            tensor::dense_forward(&w, bias, &a, &mut out_b);
            // `==` (not to_bits): the blocked path skips a == 0.0 terms,
            // which can only ever differ in the sign of a zero.
            assert_eq!(out_n, out_b, "{tag}: forward (bias={})", bias.is_some());
        }

        let mut gw_n = vec![0f32; w.len()];
        let mut gb_n = vec![0f32; output];
        let mut da_n = vec![0f32; input];
        let mut gw_b = vec![0f32; w.len()];
        let mut gb_b = vec![0f32; output];
        let mut da_b = vec![0f32; input];
        tensor::dense_backward_ref(
            &w,
            &a,
            &dy,
            &mut gw_n,
            Some(&mut gb_n),
            Some(&mut da_n),
        );
        tensor::dense_backward(&w, &a, &dy, &mut gw_b, Some(&mut gb_b), Some(&mut da_b));
        assert_eq!(bits(&gw_n), bits(&gw_b), "{tag}: gw");
        assert_eq!(bits(&gb_n), bits(&gb_b), "{tag}: gb");
        assert_eq!(da_n, da_b, "{tag}: da");
    }
}

// --- fused quantize epilogue vs separate passes -----------------------------

#[test]
fn fused_weight_prologue_matches_separate_pass_per_quantizer() {
    for name in ["luq4", "uniform4", "fp8"] {
        let cfg = TrainConfig {
            quantizer: name.into(),
            ..TrainConfig::default()
        };
        let exec = NativeExecutor::from_config(&cfg, 16 * 16 * 3, 10).unwrap();
        let model = exec.model();
        let w = exec.initial_weights();
        let nl = exec.n_quant_layers();
        let mut mask = vec![0f32; nl];
        mask[0] = 1.0;
        mask[nl - 1] = 1.0;
        let q = quant::by_name(name).unwrap();
        let seed = 1.5f32;

        // The separate pass (the pre-fusion public API, still the
        // contract): full quantized weight set.
        let separate = quantize_masked_weights(model, &w, &mask, q.as_ref(), seed);

        // The fused prologue: per-layer tensors + Some/None placement.
        let epi = QuantEpilogue::new(q.as_ref(), &mask, seed);
        let store = epi.quantized_weight_store(model, &w);
        assert_eq!(store.len(), w.len(), "{name}: store covers all params");
        for l in 0..nl {
            let wi = model.weight_index(l);
            if mask[l] > 0.0 {
                let fused = store[wi].as_deref().expect("masked layer quantized");
                assert_eq!(bits(fused), bits(&separate[wi]), "{name}: layer {l}");
                assert_eq!(
                    bits(&epi.quantize_weight(l, &w[wi])),
                    bits(&separate[wi]),
                    "{name}: quantize_weight layer {l}"
                );
            }
        }
        for (ti, slot) in store.iter().enumerate() {
            if slot.is_none() {
                assert_eq!(
                    bits(&w[ti]),
                    bits(&separate[ti]),
                    "{name}: unmasked tensor {ti} untouched by separate pass too"
                );
            }
        }
    }
}

#[test]
fn fused_grad_epilogue_matches_manual_separate_pass() {
    // Single dense layer (logreg): the whole fused per-sample flow —
    // quantized weight views in, grad epilogue at the producer point —
    // is replayed by hand with the separate-pass primitives and must
    // agree bit-for-bit, RNG stream included.
    let input = 12usize;
    let classes = 4usize;
    let model = Model::by_name("logreg", input, classes).unwrap();
    let w = model.init_weights(9);
    let mask = vec![1f32; model.n_layers()];
    let seed = 2.5f32;
    let mut xrng = Xoshiro256::seed_from_u64(77);
    for name in ["luq4", "uniform4", "fp8"] {
        let q = quant::by_name(name).unwrap();
        let epi = QuantEpilogue::new(q.as_ref(), &mask, seed);
        let store = epi.quantized_weight_store(&model, &w);
        let wviews: Vec<&[f32]> = w
            .iter()
            .enumerate()
            .map(|(i, t)| store[i].as_deref().unwrap_or(t.as_slice()))
            .collect();
        let separate = quantize_masked_weights(&model, &w, &mask, q.as_ref(), seed);
        for i in 0..8usize {
            let mut x = vec![0f32; input];
            fill(&mut xrng, &mut x);
            let label = i % classes;

            // Fused path, exactly as the executor drives it.
            let mut grads = model.zero_grads();
            let mut rng_f = NativeExecutor::sample_rng(seed, i);
            let (loss_f, _) =
                model.forward_backward(&wviews, &x, label, &mut grads, Some(&epi), &mut rng_f);

            // Manual separate passes: quantized weights from the public
            // pass, forward, softmax grad, grad quantization, backward.
            // (logreg is a single bias-less dense layer, so the whole
            // backward is one dense_backward call.)
            let logits = model.forward(&separate, &x);
            let (loss_s, _, mut dy) = tensor::softmax_xent(&logits, label);
            let mut rng_s = NativeExecutor::sample_rng(seed, i);
            q.quantize(&mut dy, &mut rng_s);
            let mut gw = vec![0f32; w[0].len()];
            tensor::dense_backward(&separate[0], &x, &dy, &mut gw, None, None);

            assert_eq!(loss_f.to_bits(), loss_s.to_bits(), "{name}: sample {i} loss");
            assert_eq!(bits(&grads[0]), bits(&gw), "{name}: sample {i} gw");
        }
    }
}

#[test]
fn zero_mask_step_is_quantizer_independent() {
    // With nothing masked the fused path must collapse to the plain
    // fp32 step: two executors differing only in quantizer agree
    // bit-for-bit.
    let bsz = 8usize;
    let ds = data::generate("gtsrb", bsz, 5).unwrap();
    let batches = data::eval_batches(&ds, bsz);
    let batch = &batches[0];
    let mk = |name: &str| {
        let cfg = TrainConfig {
            quantizer: name.into(),
            physical_batch: bsz,
            ..TrainConfig::default()
        };
        NativeExecutor::from_config(&cfg, ds.example_numel, ds.n_classes).unwrap()
    };
    let e1 = mk("luq4");
    let e2 = mk("fp8");
    let w = e1.initial_weights();
    let zero = vec![0f32; e1.n_quant_layers()];
    let a = e1
        .train_step(&w, &batch.x, &batch.y, &batch.mask, &zero, 4.0)
        .unwrap();
    let b = e2
        .train_step(&w, &batch.x, &batch.y, &batch.mask, &zero, 4.0)
        .unwrap();
    assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits(), "zero-mask loss");
    for (ga, gb) in a.grad_sums.iter().zip(&b.grad_sums) {
        assert_eq!(bits(ga), bits(gb), "zero-mask grads");
    }
}

#[test]
fn whole_run_training_determinism_through_fused_path() {
    // Same config, two fresh executors: the full coordinator run (real
    // fwd/bwd, fused quantization, clipping, noise, scheduler) must be
    // bit-identical — the run-level contract PR 5's goldens pin.
    let cfg = TrainConfig {
        model: "miniconvnet".into(),
        dataset: "gtsrb".into(),
        quantizer: "luq4".into(),
        scheduler: "dpquant".into(),
        epochs: 2,
        batch_size: 32,
        dataset_size: 128,
        val_size: 64,
        seed: 3,
        ..TrainConfig::default()
    };
    let (tr, va) = data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed).unwrap();
    let run = || {
        let exec = NativeExecutor::from_config(&cfg, tr.example_numel, tr.n_classes).unwrap();
        train(&exec, &cfg, &tr, &va, &TrainerOptions::default()).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.record.best_accuracy.to_bits(),
        b.record.best_accuracy.to_bits(),
        "best accuracy"
    );
    assert_eq!(
        a.record.final_epsilon.to_bits(),
        b.record.final_epsilon.to_bits(),
        "final epsilon"
    );
    for (wa, wb) in a.final_weights.iter().zip(&b.final_weights) {
        assert_eq!(bits(wa), bits(wb), "final weights");
    }
}
