//! Tier-1 integration tests for the `TrainSession` API: checkpoint at
//! epoch k + resume must be **bit-exact** with an uninterrupted run
//! (final accuracy, ε, and the per-epoch quantized-layer schedule), on
//! the real native backend — and broken checkpoints must fail loudly.
//!
//! These tests never skip: the native backend needs no artifacts.

use dpquant::backend::NativeExecutor;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{
    Checkpoint, EpochOutcome, EventSink, NullSink, TrainEvent, TrainSession,
};
use dpquant::data::{self, Dataset};
use dpquant::metrics::RunRecord;

fn cfg() -> TrainConfig {
    TrainConfig {
        model: "logreg".into(),
        dataset: "cifar".into(),
        scheduler: "dpquant".into(),
        epochs: 4,
        dataset_size: 256,
        val_size: 64,
        batch_size: 32,
        physical_batch: 32,
        noise_multiplier: 0.8,
        lr: 0.5,
        quant_fraction: 0.5,
        analysis_interval: 2,
        analysis_samples: 16,
        seed: 9,
        ..TrainConfig::default()
    }
}

fn fixtures(cfg: &TrainConfig) -> (NativeExecutor, Dataset, Dataset) {
    let full = data::generate(&cfg.dataset, cfg.dataset_size + cfg.val_size, 8).unwrap();
    let (tr, va) = full.split(cfg.val_size);
    let exec = NativeExecutor::from_config(cfg, tr.example_numel, tr.n_classes).unwrap();
    (exec, tr, va)
}

fn ckpt_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dpquant_{tag}_{}.json", std::process::id()))
        .to_string_lossy()
        .to_string()
}

fn assert_records_bit_exact(a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.final_epsilon.to_bits(), b.final_epsilon.to_bits());
    assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
    assert_eq!(a.analysis_epsilon.to_bits(), b.analysis_epsilon.to_bits());
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.quantized_layers, y.quantized_layers, "epoch {}", x.epoch);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.val_accuracy.to_bits(), y.val_accuracy.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.epsilon.to_bits(), y.epsilon.to_bits(), "epoch {}", x.epoch);
    }
}

/// Checkpoint at *every* epoch boundary k ∈ {1, 2, 3}; each resume must
/// reproduce the uninterrupted 4-epoch run bit-exactly.
#[test]
fn resume_at_every_epoch_is_bit_exact_native() {
    let cfg = cfg();
    let (exec, tr, va) = fixtures(&cfg);

    let mut full = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
    full.run(&exec, &tr, &va, &mut NullSink).unwrap();
    let (full_record, full_weights, _) = full.finish();
    assert_eq!(full_record.epochs.len(), cfg.epochs);

    for k in 1..cfg.epochs {
        let mut head = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        for _ in 0..k {
            assert!(matches!(
                head.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap(),
                EpochOutcome::Completed { .. }
            ));
        }
        let path = ckpt_path(&format!("resume_k{k}"));
        head.checkpoint(&path).unwrap();

        let mut resumed = TrainSession::resume(&path, &exec).unwrap();
        assert_eq!(resumed.epochs_completed(), k);
        resumed.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (record, weights, _) = resumed.finish();
        std::fs::remove_file(&path).ok();

        assert_records_bit_exact(&record, &full_record);
        assert_eq!(weights, full_weights, "weights diverged after resume at k={k}");
    }
}

/// A session that truncates at the privacy budget resumes into an
/// immediately-finished session (no budget is spent twice).
#[test]
fn truncated_session_stays_finished_after_resume() {
    let mut cfg = cfg();
    cfg.scheduler = "static_random".into();
    cfg.target_epsilon = Some(2.0);
    cfg.epochs = 50;
    cfg.noise_multiplier = 1.0;
    let (exec, tr, va) = fixtures(&cfg);

    let mut session = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
    session.run(&exec, &tr, &va, &mut NullSink).unwrap();
    assert!(session.is_truncated(), "should hit the eps=2 budget");
    let epochs_ran = session.epochs_completed();
    assert!(epochs_ran < 50);

    let path = ckpt_path("truncated");
    session.checkpoint(&path).unwrap();
    let mut resumed = TrainSession::resume(&path, &exec).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(resumed.is_truncated());
    assert_eq!(
        resumed.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap(),
        EpochOutcome::Finished
    );
    assert_eq!(resumed.epochs_completed(), epochs_ran);
}

/// Corrupted and version-mismatched checkpoints are rejected loudly,
/// never half-loaded.
#[test]
fn bad_checkpoints_rejected_loudly() {
    let cfg = cfg();
    let (exec, tr, va) = fixtures(&cfg);
    let mut session = TrainSession::builder(cfg).build(&exec, &tr).unwrap();
    session.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap();

    let path = ckpt_path("bad");
    session.checkpoint(&path).unwrap();
    let good = std::fs::read_to_string(&path).unwrap();

    // Bit-flip corruption inside a hex blob.
    let corrupted = good.replace("\"weights\":[\"", "\"weights\":[\"zz");
    std::fs::write(&path, &corrupted).unwrap();
    assert!(TrainSession::resume(&path, &exec).is_err());

    // Torn write (truncated file).
    std::fs::write(&path, &good[..good.len() / 3]).unwrap();
    assert!(TrainSession::resume(&path, &exec).is_err());

    // Version from the future.
    let future = good.replace("\"version\":1", "\"version\":999");
    std::fs::write(&path, &future).unwrap();
    let err = TrainSession::resume(&path, &exec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("version 999"), "{msg}");

    // Wrong format marker.
    std::fs::write(&path, "{\"format\": \"something-else\", \"version\": 1}").unwrap();
    let err = TrainSession::resume(&path, &exec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("not a TrainSession checkpoint"), "{msg}");

    // Missing file mentions the path.
    std::fs::remove_file(&path).ok();
    let err = TrainSession::resume(&path, &exec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("dpquant_bad"), "{msg}");

    // And the untouched original still loads.
    std::fs::write(&path, &good).unwrap();
    assert!(Checkpoint::load(&path).is_ok());
    std::fs::remove_file(&path).ok();
}

/// The typed event stream carries the run's actual telemetry: epoch
/// indices are sequential, analyses land on the configured interval,
/// and each epoch's policy matches the recorded schedule.
#[test]
fn event_stream_reflects_schedule_native() {
    #[derive(Default)]
    struct Collector {
        kinds: Vec<&'static str>,
        policies: Vec<Vec<usize>>,
        analyses: Vec<usize>,
    }
    impl EventSink for Collector {
        fn on_event(&mut self, event: &TrainEvent<'_>) {
            self.kinds.push(event.kind());
            match event {
                TrainEvent::PolicySelected { policy, .. } => {
                    self.policies.push(policy.layers.clone());
                }
                TrainEvent::AnalysisCompleted { epoch, .. } => self.analyses.push(*epoch),
                _ => {}
            }
        }
    }

    let cfg = cfg();
    let (exec, tr, va) = fixtures(&cfg);
    let mut session = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
    let mut sink = Collector::default();
    session.run(&exec, &tr, &va, &mut sink).unwrap();
    let (record, _, _) = session.finish();

    // One policy per epoch, matching the recorded schedule exactly.
    assert_eq!(sink.policies.len(), record.epochs.len());
    for (p, e) in sink.policies.iter().zip(&record.epochs) {
        assert_eq!(p, &e.quantized_layers);
    }
    // Analyses on epochs 0 and 2 (interval 2, 4 epochs) — unless the
    // Poisson probe came up empty, which these sizes make impossible to
    // observe silently: assert they ran.
    assert_eq!(sink.analyses, vec![0, 2]);
    // Stream shape: starts with epoch_started, ends with epoch_completed.
    assert_eq!(sink.kinds.first(), Some(&"epoch_started"));
    assert_eq!(sink.kinds.last(), Some(&"epoch_completed"));
    assert_eq!(
        sink.kinds.iter().filter(|k| **k == "epoch_completed").count(),
        record.epochs.len()
    );
}
