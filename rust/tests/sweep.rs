//! Sweep orchestrator determinism + failure-loudness (tier-1).
//!
//! The headline invariant: a sweep at `--jobs N` produces a report
//! **byte-identical** to the same sweep at `--jobs 1` (once the
//! wall-clock fields are zeroed via the no-timing serialization). The
//! 3×2×2 grid below runs real native-backend training — the 5-layer
//! MLP over the synthetic GTSRB shapes, so the quant_fraction axis
//! selects genuinely different layer subsets — twelve times per jobs
//! setting.
//!
//! Failure contract: a worker that errors or panics mid-sweep fails the
//! whole sweep loudly, naming the offending grid point.

use dpquant::config::TrainConfig;
use dpquant::sweep::grid::GridSpec;
use dpquant::sweep::{pool, run_sweep};

fn base_cfg() -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        dataset: "gtsrb".into(),
        dataset_size: 128,
        val_size: 64,
        batch_size: 32,
        epochs: 2,
        physical_batch: 32,
        lr: 0.5,
        scheduler: "dpquant".into(),
        analysis_interval: 1,
        ..TrainConfig::default()
    }
}

#[test]
fn twelve_point_grid_byte_identical_across_jobs() {
    // quantizer (3) × quant_fraction (2) × seed (2) = 12 points, the
    // acceptance-criteria grid, on the real native backend.
    let spec = GridSpec::parse("quantizer=luq4,uniform4,fp8;quant_fraction=0.5,1.0;seed=0..1")
        .unwrap();
    let points = spec.points(&base_cfg()).unwrap();
    assert_eq!(points.len(), 12);

    let serial = run_sweep(&points, 1, false).unwrap();
    let parallel = run_sweep(&points, 4, false).unwrap();

    let a = serial.to_json(false).to_string();
    let b = parallel.to_json(false).to_string();
    assert_eq!(a, b, "--jobs 4 must be byte-identical to --jobs 1");

    // Spot-check the report is substantive, not vacuously equal.
    assert_eq!(serial.points.len(), 12);
    for (i, p) in serial.points.iter().enumerate() {
        assert_eq!(p.index, i, "results must be ordered by grid index");
        assert_eq!(p.epochs_run, 2);
        assert!(p.steps > 0, "point {i} ran no steps");
        assert!(p.final_epsilon > 0.0);
        assert!((0.0..=1.0).contains(&p.final_accuracy));
        assert_eq!(p.schedule.len(), 2);
    }
    // Different grid cells actually produce different runs: same
    // quantizer and seed, quant_fraction 0.5 (k=3 of 5 layers) vs 1.0
    // (all 5). Odometer order: index = 4*quantizer + 2*fraction + seed.
    assert_eq!(serial.points[0].schedule[0].len(), 3);
    assert_eq!(serial.points[2].schedule[0].len(), 5);
    assert_ne!(
        serial.points[0].name, serial.points[2].name,
        "run names must encode the differing k"
    );
}

#[test]
fn sweep_repeat_is_bit_reproducible() {
    // Same grid, same jobs, run twice: identical bytes including the
    // timing-free JSON — the per-run determinism the report relies on.
    let spec = GridSpec::parse("quantizer=luq4;seed=0..2").unwrap();
    let points = spec.points(&base_cfg()).unwrap();
    let a = run_sweep(&points, 2, false).unwrap().to_json(false).to_string();
    let b = run_sweep(&points, 2, false).unwrap().to_json(false).to_string();
    assert_eq!(a, b);
}

#[test]
fn failing_grid_point_fails_the_sweep_and_is_named() {
    // 'nosuchmodel' passes config validation (the model zoo is resolved
    // by the executor) but fails inside the worker — the sweep must
    // surface the grid point, not hang or skip it.
    let spec = GridSpec::parse("model=logreg,nosuchmodel;seed=0").unwrap();
    let points = spec.points(&base_cfg()).unwrap();
    let err = run_sweep(&points, 2, false).unwrap_err().to_string();
    assert!(err.contains("grid point #1"), "{err}");
    assert!(err.contains("model=nosuchmodel"), "{err}");
}

#[test]
fn mid_sweep_panic_fails_loudly_with_the_grid_point_named() {
    // Pool-level contract: a panicking worker aborts the sweep and the
    // error names the offending job index (which run_sweep maps to the
    // grid-point label, as exercised above).
    let e = pool::run_ordered(12, 4, |i| {
        if i == 7 {
            panic!("synthetic mid-sweep failure");
        }
        Ok(i * i)
    })
    .unwrap_err();
    assert_eq!(e.index, 7);
    assert!(e.message.contains("panicked"), "{e}");
    assert!(e.message.contains("synthetic mid-sweep failure"), "{e}");

    // And the non-panicking version of the same pool call succeeds with
    // index-ordered results.
    let ok = pool::run_ordered(12, 4, |i| Ok(i * i)).unwrap();
    assert_eq!(ok, (0..12).map(|i| i * i).collect::<Vec<_>>());
}
