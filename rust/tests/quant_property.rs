//! Property-style randomized tests for all three quantizers.
//!
//! Across random shapes (lengths 1..=300, including injected zeros and
//! duplicated extrema), random scales (log-uniform over six decades),
//! and random seeds, every quantizer must satisfy its format contract:
//!
//! * **round-trip error bound** — LUQ-FP4 within one octave gap (≤ the
//!   larger of α and |x|), uniform INT4 within one grid step, FP8-E5M2
//!   within 2⁻³ relative plus half a subnormal snap step;
//! * **finiteness** — NaN-free finite inputs stay NaN-free and finite;
//! * **grid closure** — outputs land on the format's representable grid;
//! * **idempotence** — re-quantizing a quantized value with the same
//!   grid parameters is the identity (per-value for the stochastic
//!   formats, whole-tensor for the deterministic FP8).
//!
//! Deterministic pseudo-randomness throughout (`Xoshiro256` from fixed
//! seeds), so a failure reproduces exactly.

use dpquant::quant::fp8::{Fp8E5M2, MAX_E5M2, MIN_NORMAL_E5M2};
use dpquant::quant::luq::{LuqFp4, EXP_LEVELS};
use dpquant::quant::uniform4::{Uniform4, LEVELS};
use dpquant::quant::{by_name, Quantizer};
use dpquant::util::gaussian::GaussianSampler;
use dpquant::util::rng::Xoshiro256;

/// Random test tensor: gaussian values at a log-uniform scale, with a
/// sprinkling of exact zeros and a duplicated max-magnitude element.
fn random_case(rng: &mut Xoshiro256, gauss: &mut GaussianSampler) -> (Vec<f32>, f32) {
    let n = 1 + rng.next_below(300) as usize;
    // Scale spans 1e-3 .. 1e3 (log-uniform); FP8 saturation needs
    // |x| <= MAX_E5M2, which 1e3 * |gauss| stays far below.
    let scale = 10f32.powf(rng.next_f32() * 6.0 - 3.0);
    let mut xs: Vec<f32> = (0..n).map(|_| scale * gauss.standard() as f32).collect();
    for x in xs.iter_mut() {
        if rng.next_f32() < 0.05 {
            *x = 0.0;
        }
    }
    // Duplicate the max-magnitude element somewhere else (exercises the
    // "max is a fixed point" paths with a non-unique max).
    if n >= 2 {
        let (imax, _) = xs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .unwrap();
        let j = rng.next_below(n as u64) as usize;
        if j != imax {
            xs[j] = -xs[imax];
        }
    }
    (xs, scale)
}

fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0f32, |m, &x| m.max(x.abs()))
}

const CASES: usize = 120;

#[test]
fn luq4_roundtrip_bound_grid_closure_and_finiteness() {
    let mut rng = Xoshiro256::seed_from_u64(0xA001);
    let mut gauss = GaussianSampler::seed_from_u64(0xB001);
    let q = by_name("luq4").unwrap();
    for case in 0..CASES {
        let (xs, scale) = random_case(&mut rng, &mut gauss);
        let m = max_abs(&xs);
        let alpha = LuqFp4::alpha(m);
        let mut ys = xs.clone();
        q.quantize(&mut ys, &mut rng);
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            assert!(y.is_finite(), "case {case} scale {scale}: q({x}) = {y}");
            // Error bound: underflow band err <= alpha; octave k err
            // < hi - lo = lo <= |x|.
            let bound = alpha.max(x.abs()) * 1.0001;
            assert!(
                (x - y).abs() <= bound,
                "case {case} elem {i}: |{x} - {y}| > {bound}"
            );
            // Grid closure: y in {0} ∪ {±alpha·2^k, k = 0..7}.
            if y != 0.0 {
                let k = (y.abs() / alpha).log2();
                assert!(
                    (k - k.round()).abs() < 1e-4
                        && (0.0..=(EXP_LEVELS - 1) as f32).contains(&k.round()),
                    "case {case} elem {i}: {y} off-grid (k = {k}, alpha = {alpha})"
                );
            }
        }
        // The max-magnitude elements sit on the top grid point and are
        // fixed points of the quantizer.
        if m > 0.0 {
            for (x, y) in xs.iter().zip(&ys) {
                if x.abs() == m {
                    assert_eq!(*y, *x, "max element must be fixed (case {case})");
                }
            }
        }
    }
}

#[test]
fn luq4_per_value_idempotent_on_its_grid() {
    // Quantizing a grid value with the same alpha returns it exactly,
    // for any stochastic draw: outputs are closed under re-quantization.
    let mut rng = Xoshiro256::seed_from_u64(0xA002);
    for _ in 0..CASES {
        let alpha = 10f32.powf(rng.next_f32() * 6.0 - 3.0);
        let x = {
            let k = rng.next_below(EXP_LEVELS as u64) as i32;
            let sign = if rng.next_f32() < 0.5 { -1.0 } else { 1.0 };
            sign * alpha * (2f32).powi(k)
        };
        for u in [0.0, 0.25, 0.5, 0.999] {
            assert_eq!(
                LuqFp4::quantize_one(x, alpha, u),
                x,
                "grid value {x} (alpha {alpha}) must be a fixed point at u={u}"
            );
        }
        // And zero is always a fixed point.
        assert_eq!(LuqFp4::quantize_one(0.0, alpha, 0.3), 0.0);
    }
}

#[test]
fn uniform4_roundtrip_bound_grid_closure_and_finiteness() {
    let mut rng = Xoshiro256::seed_from_u64(0xA003);
    let mut gauss = GaussianSampler::seed_from_u64(0xB003);
    let q = by_name("uniform4").unwrap();
    for case in 0..CASES {
        let (xs, scale) = random_case(&mut rng, &mut gauss);
        let m = max_abs(&xs);
        if m == 0.0 {
            continue;
        }
        let step = Uniform4::step(m);
        let mut ys = xs.clone();
        q.quantize(&mut ys, &mut rng);
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            assert!(y.is_finite(), "case {case} scale {scale}: q({x}) = {y}");
            assert!(
                (x - y).abs() <= step * 1.001,
                "case {case} elem {i}: |{x} - {y}| > step {step}"
            );
            let k = y / step;
            assert!(
                (k - k.round()).abs() < 1e-3,
                "case {case} elem {i}: {y} not a multiple of step {step}"
            );
        }
    }
}

#[test]
fn uniform4_exact_grid_values_are_fixed_points() {
    // With a power-of-two step every multiple k·step is exactly
    // representable, so quantize_one must return it untouched for any
    // stochastic draw — per-value idempotence on the grid.
    for step_exp in [-8i32, -2, 0, 3] {
        let step = (2f32).powi(step_exp);
        for k in -(LEVELS as i32) / 2..=(LEVELS as i32) / 2 {
            let x = k as f32 * step;
            for u in [0.0, 0.4999, 0.5, 0.999] {
                assert_eq!(
                    Uniform4::quantize_one(x, step, u),
                    x,
                    "k={k} step={step} u={u}"
                );
            }
        }
    }
}

#[test]
fn fp8_roundtrip_bound_and_finiteness() {
    let mut rng = Xoshiro256::seed_from_u64(0xA004);
    let mut gauss = GaussianSampler::seed_from_u64(0xB004);
    let q = by_name("fp8").unwrap();
    let subnormal_step = MIN_NORMAL_E5M2 / 4.0;
    for case in 0..CASES {
        let (xs, scale) = random_case(&mut rng, &mut gauss);
        let mut ys = xs.clone();
        q.quantize(&mut ys, &mut rng);
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            assert!(y.is_finite(), "case {case} scale {scale}: q({x}) = {y}");
            // Normal range: <= 2^-3 relative (2 mantissa bits); the
            // subnormal band adds up to half a 2^-16 snap step on top
            // of the mantissa rounding, so the bounds compose additively
            // at the boundary.
            let bound = 0.125 * x.abs() + 0.5001 * subnormal_step;
            assert!(
                (x - y).abs() <= bound,
                "case {case} elem {i}: |{x} - {y}| > {bound}"
            );
        }
    }
}

#[test]
fn fp8_whole_tensor_idempotent() {
    // FP8 is deterministic, so idempotence holds tensor-wide: quantizing
    // twice equals quantizing once, bit for bit.
    let mut rng = Xoshiro256::seed_from_u64(0xA005);
    let mut gauss = GaussianSampler::seed_from_u64(0xB005);
    let q = by_name("fp8").unwrap();
    for _ in 0..CASES {
        let (xs, _) = random_case(&mut rng, &mut gauss);
        let mut once = xs.clone();
        q.quantize(&mut once, &mut rng);
        let mut twice = once.clone();
        q.quantize(&mut twice, &mut rng);
        assert_eq!(once, twice);
    }
    // Saturation edge: beyond-max values clamp to the max, which is a
    // fixed point.
    assert_eq!(Fp8E5M2::quantize_one(1e30), MAX_E5M2);
    assert_eq!(Fp8E5M2::quantize_one(MAX_E5M2), MAX_E5M2);
}

#[test]
fn stochastic_formats_roundtrip_unbiased_on_random_tensors() {
    // E[q(x)] = x coordinate-wise: a randomized spot-check of the
    // unbiasedness Proposition 1 requires, on a fresh random tensor
    // (the in-module tests pin this on fixed vectors).
    let mut rng = Xoshiro256::seed_from_u64(0xA006);
    let mut gauss = GaussianSampler::seed_from_u64(0xB006);
    let xs: Vec<f32> = (0..64).map(|_| gauss.standard() as f32).collect();
    for name in ["luq4", "uniform4"] {
        let q = by_name(name).unwrap();
        let trials = 4000;
        let mut acc = vec![0f64; xs.len()];
        let mut buf = vec![0f32; xs.len()];
        for _ in 0..trials {
            buf.copy_from_slice(&xs);
            q.quantize(&mut buf, &mut rng);
            for (a, &b) in acc.iter_mut().zip(&buf) {
                *a += b as f64;
            }
        }
        for (i, (&x, a)) in xs.iter().zip(&acc).enumerate() {
            let mean = a / trials as f64;
            assert!(
                (mean - x as f64).abs() < 0.1,
                "{name} elem {i}: E[q({x})] = {mean}"
            );
        }
    }
}

#[test]
fn all_quantizers_preserve_zero_tensors_and_zeros() {
    let mut rng = Xoshiro256::seed_from_u64(0xA007);
    for name in ["luq4", "uniform4", "fp8"] {
        let q: Box<dyn Quantizer> = by_name(name).unwrap();
        let mut zeros = vec![0f32; 33];
        q.quantize(&mut zeros, &mut rng);
        assert!(zeros.iter().all(|&v| v == 0.0), "{name} must fix the zero tensor");
        // Zeros embedded in a nonzero tensor stay zero too.
        let mut mixed = vec![0.0f32, 1.5, 0.0, -2.25, 0.0];
        q.quantize(&mut mixed, &mut rng);
        assert_eq!(mixed[0], 0.0, "{name}");
        assert_eq!(mixed[2], 0.0, "{name}");
        assert_eq!(mixed[4], 0.0, "{name}");
    }
}
