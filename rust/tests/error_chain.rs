//! Integration tests for the homegrown error subsystem, exercised from
//! *outside* the crate (validates the `$crate` macro paths and the
//! `util::error` re-exports the way downstream code — the CLI, benches,
//! examples — consumes them).

use dpquant::util::error::{bail, ensure, err, Context, Error, Result};

fn parse_port(s: &str) -> Result<u16> {
    ensure!(!s.is_empty(), "empty port");
    let n: u64 = s.parse().with_context(|| format!("parsing port '{s}'"))?;
    if n > u64::from(u16::MAX) {
        bail!("port {n} out of range");
    }
    Ok(n as u16)
}

#[test]
fn macros_work_across_the_crate_boundary() {
    assert_eq!(parse_port("8080").unwrap(), 8080);
    assert_eq!(format!("{}", parse_port("").unwrap_err()), "empty port");
    assert_eq!(
        format!("{}", parse_port("70000").unwrap_err()),
        "port 70000 out of range"
    );

    let e = parse_port("abc").unwrap_err();
    assert_eq!(format!("{e}"), "parsing port 'abc'");
    // The std ParseIntError survives as the root-cause frame.
    assert_eq!(e.chain().count(), 2);

    // The bare err! form, via the module re-export.
    assert_eq!(format!("{}", err!("x = {}", 3)), "x = 3");
}

#[test]
fn alternate_display_joins_the_chain() {
    let e = Error::msg("root").context("mid").context("top");
    assert_eq!(format!("{e:#}"), "top: mid: root");
}

#[test]
fn io_errors_convert_through_question_mark() {
    fn read() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/dpquant/error_chain")?;
        Ok(s)
    }
    let e = read().unwrap_err();
    assert!(!format!("{e}").is_empty());
}

#[test]
fn runtime_open_reports_missing_artifacts_with_context() {
    // The exact failure CI sees without `make artifacts`: the error chain
    // must point at the manifest and at the remedy, not panic.
    let e = dpquant::runtime::Runtime::open("/nonexistent/artifacts-dir").unwrap_err();
    let full = format!("{e:#}");
    assert!(full.contains("manifest.json"), "{full}");
    assert!(full.contains("make artifacts"), "{full}");
}
