//! Tier-1 observability tests: the acceptance contract of
//! `rust/src/obs/` (DESIGN.md §14).
//!
//! (a) **Golden schema**: a real 2-epoch native run traced through
//!     `JsonlSink` + coarse epoch spans produces a `dpquant-trace` v1
//!     file whose header, record shape, and zeroed timings all
//!     validate — and whose bytes are identical when the identical run
//!     repeats (`--no-timing` traces are diffable).
//! (b) **Histogram properties**: bounds are sanitized to a strictly
//!     increasing sequence, counts are conserved across buckets plus
//!     overflow, and p95 never leaves the observed `[min, max]`.
//! (c) **Pure observation**: a traced run's final metrics line and
//!     final weights (bit-for-bit) are identical to an untraced run's —
//!     tracing can never perturb training.

use dpquant::backend;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{NullSink, TrainSession};
use dpquant::data;
use dpquant::obs::{trace, JsonlSink, MetricsRegistry, TraceWriter};
use dpquant::util::json::{self, Json};
use dpquant::util::rng::Xoshiro256;

/// The fast real-training config the serve tests also use.
fn cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "logreg".into(),
        backend: "native".into(),
        dataset_size: 192,
        val_size: 64,
        batch_size: 16,
        physical_batch: 64,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dpquant_obs_{tag}_{}.trace.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Run `cfg` to completion, optionally tracing to `trace_path` with
/// timing off — the same wiring `dpquant train --trace-out PATH
/// --no-timing` uses (JsonlSink event stream + one `step_epoch` span
/// per epoch). Returns the training outputs the determinism contract
/// pins: the final metrics line and every final weight bit.
fn run(cfg: &TrainConfig, trace_path: Option<&str>) -> (String, Vec<Vec<u32>>) {
    let (train_ds, val_ds) =
        data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed).unwrap();
    let exec =
        backend::open_sweep_executor(cfg, train_ds.example_numel, train_ds.n_classes).unwrap();
    let mut session = TrainSession::builder(cfg.clone()).build(exec.as_ref(), &train_ds).unwrap();
    let writer = trace_path.map(|p| TraceWriter::create(p, false).unwrap());
    let mut jsonl = writer.as_ref().map(JsonlSink::new);
    while !session.is_finished() {
        let _span = writer.as_ref().map(|w| {
            w.span(
                "step_epoch",
                "session",
                json::obj(vec![("epoch", json::num(session.epochs_completed() as f64))]),
            )
        });
        match &mut jsonl {
            Some(sink) => session.step_epoch(exec.as_ref(), &train_ds, &val_ds, sink).unwrap(),
            None => session.step_epoch(exec.as_ref(), &train_ds, &val_ds, &mut NullSink).unwrap(),
        };
    }
    if let Some(w) = writer.as_ref() {
        w.finish().unwrap();
    }
    let bits = session
        .weights()
        .iter()
        .map(|t| t.iter().map(|x| x.to_bits()).collect())
        .collect();
    (session.record().final_line(), bits)
}

// ---------------------------------------------------------------------
// (a) golden dpquant-trace v1 schema on a real 2-epoch run
// ---------------------------------------------------------------------

#[test]
fn trace_schema_golden_on_a_two_epoch_run() {
    let path = tmp("golden");
    let c = cfg(3, 2);
    run(&c, Some(&path));

    // The file validates end to end (header, record shape, unique ids,
    // parents referencing earlier spans, zero event durations).
    let stats = trace::check(&path).unwrap();
    // One span per epoch plus the final probe call that observes
    // `Finished` (mirroring the CLI loop in main.rs).
    assert_eq!(stats.spans, 3);
    assert!(
        stats.events >= 4,
        "at least epoch_started + epoch_completed per epoch, got {}",
        stats.events
    );

    let text = std::fs::read_to_string(&path).unwrap();
    let mut lines = text.lines();
    assert_eq!(
        lines.next().unwrap(),
        "{\"format\":\"dpquant-trace\",\"version\":1}",
        "golden header line"
    );
    for line in lines {
        let j = json::parse(line).unwrap();
        let ty = j.get("type").unwrap().as_str().unwrap();
        assert!(ty == "span" || ty == "event", "{line}");
        assert!(j.get("id").unwrap().as_usize().unwrap() >= 1, "{line}");
        assert!(!j.get("name").unwrap().as_str().unwrap().is_empty(), "{line}");
        assert_eq!(j.get("target").unwrap().as_str(), Some("session"), "{line}");
        assert!(j.get("fields").unwrap().as_obj().is_some(), "{line}");
        // Timing off: every timestamp and duration is exactly zero.
        assert_eq!(j.get("start_ns").unwrap().as_f64(), Some(0.0), "{line}");
        assert_eq!(j.get("dur_ns").unwrap().as_f64(), Some(0.0), "{line}");
    }
    for name in ["epoch_started", "policy_selected", "epoch_completed", "step_epoch"] {
        assert!(text.contains(&format!("\"name\":\"{name}\"")), "missing {name}:\n{text}");
    }
    // Session events nest under the epoch span open when they fired.
    assert!(text.contains("\"parent\":1"), "{text}");

    // `trace summarize` aggregates the spans per target.
    let rows = trace::summarize(&path).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].target, "session");
    assert_eq!(rows[0].count, 3);
    assert_eq!(rows[0].total_ns, 0.0);
    assert_eq!(rows[0].p95_ns, 0.0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn zeroed_timing_traces_are_byte_identical_across_runs() {
    let (pa, pb) = (tmp("det_a"), tmp("det_b"));
    let c = cfg(11, 2);
    run(&c, Some(&pa));
    run(&c, Some(&pb));
    let (a, b) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    assert!(!a.is_empty());
    assert_eq!(a, b, "--no-timing traces of identical runs must diff clean");
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

// ---------------------------------------------------------------------
// (b) histogram properties
// ---------------------------------------------------------------------

#[test]
fn histogram_bounds_sanitized_and_counts_conserved() {
    let reg = MetricsRegistry::new();
    // Unsorted, duplicated, and non-finite bounds are sanitized into a
    // strictly increasing finite sequence.
    let h = reg.histogram(
        "t.conserve",
        &[500.0, 10.0, f64::NAN, 10.0, 100.0, f64::INFINITY],
    );
    assert_eq!(h.bounds(), &[10.0, 100.0, 500.0]);
    assert!(h.bounds().windows(2).all(|w| w[0] < w[1]));

    let mut rng = Xoshiro256::seed_from_u64(7);
    let n = 10_000usize;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for _ in 0..n {
        let v = f64::from(rng.next_f32()) * 1000.0;
        lo = lo.min(v);
        hi = hi.max(v);
        h.record(v);
    }
    // Non-finite observations are dropped, never counted.
    h.record(f64::NAN);
    h.record(f64::INFINITY);
    assert_eq!(h.count(), n as u64);
    // Count conservation: bucket counts (incl. overflow) sum to count.
    assert_eq!(h.bucket_counts().iter().sum::<u64>(), n as u64);
    assert_eq!(h.min(), lo);
    assert_eq!(h.max(), hi);
    assert!(h.mean() >= lo && h.mean() <= hi);
}

#[test]
fn histogram_p95_stays_within_observed_range() {
    let reg = MetricsRegistry::new();
    let mut rng = Xoshiro256::seed_from_u64(21);
    for case in 0..8u64 {
        let h = reg.histogram_ns(&format!("t.p95.{case}"));
        let n = 1 + (case as usize) * 37;
        let scale = 10f64.powi((case % 7) as i32);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for _ in 0..n {
            let v = f64::from(rng.next_f32()) * scale + 1.0;
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        let p95 = h.p95();
        assert!(
            p95 >= lo && p95 <= hi,
            "case {case}: p95 {p95} left the observed [{lo}, {hi}]"
        );
    }
    // Empty histogram: everything finite and zero.
    let empty = reg.histogram_ns("t.p95.empty");
    assert_eq!(empty.count(), 0);
    assert_eq!(empty.p95(), 0.0);
    assert_eq!(empty.mean(), 0.0);
}

// ---------------------------------------------------------------------
// (c) tracing is pure observation
// ---------------------------------------------------------------------

#[test]
fn traced_and_untraced_runs_produce_identical_outputs() {
    let path = tmp("inert");
    let c = cfg(17, 2);
    let (line_traced, bits_traced) = run(&c, Some(&path));
    let (line_plain, bits_plain) = run(&c, None);
    assert_eq!(
        line_traced, line_plain,
        "the final metrics line must not move when tracing is on"
    );
    assert_eq!(
        bits_traced, bits_plain,
        "final weights must be bit-identical with tracing on or off"
    );
    // And the trace really was written.
    let stats = trace::check(&path).unwrap();
    assert!(stats.events > 0 && stats.spans > 0, "{stats:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_traces_are_rejected_with_positions() {
    let path = tmp("reject");
    // Valid header, then a record with a dur on an event (illegal).
    std::fs::write(
        &path,
        "{\"format\":\"dpquant-trace\",\"version\":1}\n\
         {\"dur_ns\":5,\"fields\":{},\"id\":1,\"name\":\"x\",\"parent\":null,\
         \"start_ns\":0,\"target\":\"t\",\"type\":\"event\"}\n",
    )
    .unwrap();
    let e = trace::check(&path).unwrap_err().to_string();
    assert!(e.contains("line 2"), "{e}");
    // Wrong format tag in the header.
    std::fs::write(&path, "{\"format\":\"nope\",\"version\":1}\n").unwrap();
    assert!(trace::check(&path).is_err());
    std::fs::remove_file(&path).ok();
}

/// `Json` is re-exported through util::json; silence the unused-import
/// trap by using it for a structural assertion on the metrics doc.
#[test]
fn metrics_doc_shape_is_stable() {
    let doc = dpquant::obs::metrics_doc();
    assert!(matches!(doc, Json::Obj(_)));
    assert_eq!(doc.get("format").unwrap().as_str(), Some("dpquant-metrics"));
    assert!(doc.get("metrics").unwrap().get("histograms").is_some());
}
