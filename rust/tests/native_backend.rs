//! Integration tests for the native pure-Rust execution backend — the
//! default `cargo test -q` path that exercises the **full coordinator
//! loop** (Poisson sampling, Algorithms 1–2, DP-SGD, RDP accounting)
//! with zero artifacts.
//!
//! The parity tests pin the backend's numerics: with an all-zero
//! `quant_mask` the logistic-regression model must match a hand-computed
//! softmax-regression gradient and agree with `MockExecutor`'s clipping
//! semantics (Σ of clipped per-sample grads, loss/correct sums).

use dpquant::backend::NativeExecutor;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, MockExecutor, StepExecutor, TrainerOptions};
use dpquant::data;
use dpquant::privacy::Mechanism;
use dpquant::util::rng::Xoshiro256;

#[test]
fn parity_logreg_matches_hand_computed_gradient() {
    // clip_norm huge => per-sample clipping is a no-op, so the grad sum
    // is the plain softmax-regression gradient.
    let cfg = TrainConfig {
        model: "logreg".into(),
        clip_norm: 1e6,
        physical_batch: 2,
        seed: 5,
        ..TrainConfig::default()
    };
    let exec = NativeExecutor::from_config(&cfg, 4, 3).unwrap();
    let weights = exec.initial_weights();
    assert_eq!(weights.len(), 1, "logreg has a single weight tensor");
    let x = vec![0.5f32, -1.0, 0.25, 2.0, 1.5, 0.0, -0.75, 1.0];
    let y = vec![2i32, 0];
    let mask = vec![1.0f32, 1.0];
    let zero_mask = vec![0f32; 1];
    let out = exec.train_step(&weights, &x, &y, &mask, &zero_mask, 0.0).unwrap();

    // Hand-computed: g[c,f] = Σ_samples (softmax_c - 1{c=y}) * x_f.
    let w = &weights[0];
    let mut expect = vec![0f64; 12];
    let mut loss = 0f64;
    for s in 0..2usize {
        let xs = &x[s * 4..(s + 1) * 4];
        let logits: Vec<f64> = (0..3)
            .map(|c| (0..4).map(|f| w[c * 4 + f] as f64 * xs[f] as f64).sum())
            .collect();
        let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|l| (l - maxl).exp()).collect();
        let z: f64 = exps.iter().sum();
        let label = y[s] as usize;
        loss += z.ln() + maxl - logits[label];
        for c in 0..3 {
            let p = exps[c] / z - if c == label { 1.0 } else { 0.0 };
            for f in 0..4 {
                expect[c * 4 + f] += p * xs[f] as f64;
            }
        }
    }
    for (i, (&g, &e)) in out.grad_sums[0].iter().zip(&expect).enumerate() {
        assert!((g as f64 - e).abs() < 1e-5, "grad[{i}]: {g} vs {e}");
    }
    assert!((out.loss_sum as f64 - loss).abs() < 1e-4, "{} vs {loss}", out.loss_sum);
}

#[test]
fn parity_matches_mock_executor_clipping_semantics() {
    let (feats, classes, b) = (6usize, 3usize, 8usize);
    let mut mock = MockExecutor::new(feats, classes, 4, b);
    mock.clip_norm = 1.0;
    let cfg = TrainConfig {
        model: "logreg".into(),
        clip_norm: 1.0,
        physical_batch: b,
        ..TrainConfig::default()
    };
    let native = NativeExecutor::from_config(&cfg, feats, classes).unwrap();

    // Shared non-trivial weights and a batch with one masked-out row.
    let mut rng = Xoshiro256::seed_from_u64(77);
    let w: Vec<f32> = (0..classes * feats).map(|_| rng.next_f32() - 0.5).collect();
    let weights = vec![w];
    let mut x = vec![0f32; b * feats];
    let mut y = vec![0i32; b];
    for i in 0..b {
        let c = rng.next_below(classes as u64) as i32;
        y[i] = c;
        for f in 0..feats {
            x[i * feats + f] = rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 };
        }
    }
    let mut mask = vec![1.0f32; b];
    mask[b - 1] = 0.0;

    // Zero quant masks on both sides (mock schedules 4 pseudo-layers,
    // the native logreg has 1 real layer).
    let m = mock
        .train_step(&weights, &x, &y, &mask, &[0.0; 4], 0.0)
        .unwrap();
    let n = native
        .train_step(&weights, &x, &y, &mask, &[0.0; 1], 0.0)
        .unwrap();
    assert_eq!(m.grad_sums.len(), n.grad_sums.len());
    for (i, (a, c)) in m.grad_sums[0].iter().zip(&n.grad_sums[0]).enumerate() {
        assert!((a - c).abs() < 1e-5, "grad[{i}]: mock {a} vs native {c}");
    }
    assert!((m.loss_sum - n.loss_sum).abs() < 1e-4);
    assert_eq!(m.correct_sum, n.correct_sum);
    assert!((m.raw_norm_sum - n.raw_norm_sum).abs() < 1e-4);
    assert!((m.raw_norm_max - n.raw_norm_max).abs() < 1e-5);

    let me = mock.eval_step(&weights, &x, &y, &mask).unwrap();
    let ne = native.eval_step(&weights, &x, &y, &mask).unwrap();
    assert!((me.loss_sum - ne.loss_sum).abs() < 1e-4);
    assert_eq!(me.correct_sum, ne.correct_sum);
}

/// Tier-1 gate: the full DPQuant pipeline (PLS + LLP scheduling, DP
/// noise, RDP accounting) trains the native MLP for 2 epochs on the
/// synthetic CIFAR stand-in and lands above chance with no artifacts.
#[test]
fn native_two_epochs_trains_above_chance() {
    let cfg = TrainConfig {
        model: "mlp".into(),
        dataset: "cifar".into(),
        quantizer: "luq4".into(),
        scheduler: "dpquant".into(),
        epochs: 2,
        dataset_size: 1536,
        val_size: 256,
        batch_size: 64,
        physical_batch: 64,
        noise_multiplier: 0.2,
        clip_norm: 1.0,
        lr: 1.0,
        quant_fraction: 0.5,
        seed: 1,
        ..TrainConfig::default()
    };
    let full = data::generate("cifar", cfg.dataset_size + cfg.val_size, 42).unwrap();
    let (tr, va) = full.split(cfg.val_size);
    let exec = NativeExecutor::from_config(&cfg, tr.example_numel, tr.n_classes).unwrap();
    let res = train(&exec, &cfg, &tr, &va, &TrainerOptions::default()).unwrap();
    assert_eq!(res.record.epochs.len(), 2);
    // 10-class task, chance = 0.10.
    assert!(
        res.record.best_accuracy > 0.15,
        "accuracy {} not above chance",
        res.record.best_accuracy
    );
    let first = res.record.epochs[0].train_loss;
    let last = res.record.epochs[1].train_loss;
    assert!(last < first, "train loss should fall: {first} -> {last}");
    assert!(res.record.final_epsilon > 0.0);
    // k = round(5 * 0.5) = 3 of the MLP's 5 layers quantized per epoch.
    for e in &res.record.epochs {
        assert_eq!(e.quantized_layers.len(), 3);
    }
    // Algorithm 1 ran once (epoch 0; interval 2) and was accounted.
    assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 1);
    assert_eq!(res.accountant.steps_of(Mechanism::Training), 2 * (1536 / 64));
}

/// The mini-CNN path: conv backward, pooling, logical > physical batch
/// chunking, and a rotating PLS schedule — all live, no artifacts.
#[test]
fn native_cnn_coordinator_smoke() {
    let cfg = TrainConfig {
        model: "miniconvnet".into(),
        dataset: "gtsrb".into(),
        quantizer: "fp8".into(),
        scheduler: "pls".into(),
        epochs: 2,
        dataset_size: 256,
        val_size: 64,
        batch_size: 64,
        physical_batch: 32, // logical 64 > physical 32: exercises chunked accumulation
        noise_multiplier: 0.1,
        clip_norm: 1.0,
        lr: 0.5,
        quant_fraction: 0.75,
        seed: 3,
        ..TrainConfig::default()
    };
    let full = data::generate("gtsrb", cfg.dataset_size + cfg.val_size, 11).unwrap();
    let (tr, va) = full.split(cfg.val_size);
    let exec = NativeExecutor::from_config(&cfg, tr.example_numel, tr.n_classes).unwrap();
    let res = train(&exec, &cfg, &tr, &va, &TrainerOptions::default()).unwrap();
    assert_eq!(res.record.epochs.len(), 2);
    assert!(res.record.epochs.iter().all(|e| e.train_loss.is_finite()));
    let first = res.record.epochs[0].train_loss;
    let last = res.record.epochs[1].train_loss;
    assert!(last < first, "CNN loss should fall: {first} -> {last}");
    // PLS quantizes k = round(5 * 0.75) = 4 of 5 layers every epoch.
    for e in &res.record.epochs {
        assert_eq!(e.quantized_layers.len(), 4);
    }
}

/// Whole-run determinism on the native backend: same seed, same result.
#[test]
fn native_training_deterministic_given_seed() {
    let cfg = TrainConfig {
        model: "logreg".into(),
        dataset: "cifar".into(),
        scheduler: "static_random".into(),
        epochs: 2,
        dataset_size: 256,
        val_size: 64,
        batch_size: 32,
        physical_batch: 32,
        noise_multiplier: 0.5,
        lr: 0.5,
        quant_fraction: 1.0,
        seed: 9,
        ..TrainConfig::default()
    };
    let run = || {
        let full = data::generate("cifar", cfg.dataset_size + cfg.val_size, 8).unwrap();
        let (tr, va) = full.split(cfg.val_size);
        let exec = NativeExecutor::from_config(&cfg, tr.example_numel, tr.n_classes).unwrap();
        train(&exec, &cfg, &tr, &va, &TrainerOptions::default()).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.record.final_accuracy, b.record.final_accuracy);
    assert_eq!(a.record.final_epsilon, b.record.final_epsilon);
    assert_eq!(a.final_weights, b.final_weights);
}
