//! Golden-value tests for the RDP accountant.
//!
//! Epsilon at fixed (q, σ, steps, δ) tuples — and the raw per-step RDP
//! at fixed (q, σ, α) — is pinned against precomputed reference values,
//! so a refactor of `privacy/rdp.rs` (series cutoffs, log-space
//! plumbing, α grid, the (ε, δ) conversion) cannot silently drift the
//! privacy accounting.
//!
//! Provenance of the reference values: an independent line-by-line port
//! of the same Mironov-et-al. closed-form/series analysis to Python
//! (stdlib only: `math.lgamma`, `math.erfc`, identical asymptotic
//! `log_erfc` branch and series cutoffs), cross-checked against direct
//! numerical integration of the SGM Rényi divergence (the
//! `python/tests/test_accountant_oracle.py` integrand) to ~1e-11
//! relative. The 1e-6 relative tolerance below leaves ~5 decades of
//! headroom over float-op-reordering noise while still catching any
//! real change in the math.

use dpquant::config::TrainConfig;
use dpquant::coordinator::{
    adaptive, AdaptivePolicy, DecayShape, EpochKnobs, MockExecutor, NullSink, TrainSession,
};
use dpquant::data::Dataset;
use dpquant::privacy::{
    default_alphas, rdp_sgm_step, rdp_to_epsilon, Mechanism, RdpAccountant, StepRecord,
};
use dpquant::serve::ledger::BudgetLedger;
use dpquant::util::rng::Xoshiro256;

const REL_TOL: f64 = 1e-6;

fn assert_rel(got: f64, want: f64, what: &str) {
    let rel = (got - want).abs() / want.abs().max(1e-300);
    assert!(
        rel < REL_TOL,
        "{what}: got {got:.15e}, want {want:.15e} (rel {rel:.3e})"
    );
}

#[test]
fn rdp_step_golden_values() {
    // (q, sigma, alpha) -> rho. Integer α exercises the closed-form
    // binomial sum, fractional α the two-sided series.
    let cases: &[(f64, f64, f64, f64)] = &[
        (0.01, 1.0, 2.0, 0.00017181342207451406),
        (0.01, 1.0, 32.0, 11.246275937048072),
        (0.02, 1.2, 4.5, 0.0009658764840110198),
        (0.2, 2.0, 8.0, 0.06495195153203882),
        (256.0 / 60_000.0, 1.1, 1.5, 1.74797844630243e-5),
        (0.05, 0.7, 3.3, 0.0786472873492649),
    ];
    for &(q, sigma, alpha, want) in cases {
        assert_rel(
            rdp_sgm_step(q, sigma, alpha),
            want,
            &format!("rho(q={q}, sigma={sigma}, alpha={alpha})"),
        );
    }
    // q = 1 is the plain Gaussian mechanism: alpha / (2 sigma^2), exact.
    assert_eq!(rdp_sgm_step(1.0, 5.0, 3.5), 3.5 / 50.0);
}

/// ε over the default α grid for a homogeneous training schedule.
fn epsilon_of_schedule(q: f64, sigma: f64, steps: u64, delta: f64) -> (f64, f64) {
    let alphas = default_alphas();
    let curve: Vec<f64> = alphas
        .iter()
        .map(|&a| steps as f64 * rdp_sgm_step(q, sigma, a))
        .collect();
    rdp_to_epsilon(&alphas, &curve, delta)
}

#[test]
fn epsilon_golden_values() {
    // (q, sigma, steps, delta) -> (eps, best alpha). The alpha pin is
    // loose (a near-tie can flip the argmin between neighboring grid
    // points without moving eps measurably).
    let cases: &[(f64, f64, u64, f64, f64, f64)] = &[
        (1.0, 5.0, 1, 1e-5, 0.794522032537103, 22.0),
        (0.01, 1.0, 1000, 1e-5, 2.101365271648395, 7.8),
        (0.02, 1.0, 1000, 1e-5, 4.324153229780495, 5.1),
        // The canonical DP-SGD literature config (MNIST-scale: B = 256,
        // |D| = 60k, sigma = 1.1, 60 epochs): eps ~= 2.6 — the tight
        // version of the band `rdp.rs`'s own test asserts.
        (256.0 / 60_000.0, 1.1, 14_062, 1e-5, 2.596555868953751, 8.1),
        (0.05, 2.0, 5000, 1e-6, 11.037150232617474, 3.6),
        (0.1, 0.7, 50, 1e-5, 12.264057614411445, 2.3),
        (0.015625, 0.6, 128, 1e-5, 6.490633236096604, 3.0),
    ];
    for &(q, sigma, steps, delta, want_eps, want_alpha) in cases {
        let (eps, alpha) = epsilon_of_schedule(q, sigma, steps, delta);
        let what = format!("eps(q={q}, sigma={sigma}, steps={steps}, delta={delta})");
        assert_rel(eps, want_eps, &what);
        assert!(
            (alpha - want_alpha).abs() < 0.5,
            "{what}: best alpha {alpha}, expected near {want_alpha}"
        );
    }
}

#[test]
fn accountant_composition_golden() {
    // The accountant composes a training schedule with analysis steps by
    // adding RDP curves; pin the composed ε and both single-mechanism
    // attributions. (Training: q = 1/16, sigma = 0.6, 64 steps;
    // analysis: q = 1/32, sigma_measure = 0.5, 3 invocations.)
    let mut acc = RdpAccountant::new();
    acc.step_training(0.0625, 0.6, 64);
    for _ in 0..3 {
        acc.step_analysis(0.03125, 0.5);
    }
    let delta = 1e-5;
    assert_rel(acc.epsilon(delta).0, 13.571260089202578, "composed eps");
    assert_rel(
        acc.epsilon_of(Mechanism::Training, delta).0,
        13.324807736901857,
        "training-only eps",
    );
    assert_rel(
        acc.epsilon_of(Mechanism::Analysis, delta).0,
        6.853674671286486,
        "analysis-only eps",
    );
    // Attribution bookkeeping stays exact.
    assert_eq!(acc.steps_of(Mechanism::Training), 64);
    assert_eq!(acc.steps_of(Mechanism::Analysis), 3);
}

#[test]
fn ledger_spend_composes_like_one_accountant() {
    // The budget ledger's contract (DESIGN.md §15): a tenant that runs
    // two jobs sequentially must be charged EXACTLY what one accountant
    // composing both runs' histories would report — debit-by-debit
    // replay cannot drift from straight-line composition, bit for bit.
    let h1 = [
        StepRecord {
            mechanism: Mechanism::Training,
            sample_rate: 0.0625,
            noise_multiplier: 0.6,
            steps: 64,
        },
        StepRecord {
            mechanism: Mechanism::Analysis,
            sample_rate: 0.03125,
            noise_multiplier: 0.5,
            steps: 3,
        },
    ];
    let h2 = [
        StepRecord {
            mechanism: Mechanism::Training,
            sample_rate: 0.02,
            noise_multiplier: 1.0,
            steps: 500,
        },
        StepRecord {
            mechanism: Mechanism::Analysis,
            sample_rate: 0.03125,
            noise_multiplier: 0.5,
            steps: 2,
        },
    ];
    let delta = 1e-5;

    // One accountant, both runs straight through.
    let mut acc = RdpAccountant::new();
    for r in h1.iter().chain(h2.iter()) {
        acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
    }
    let composed = acc.epsilon(delta).0;

    // The ledger: two reserve → debit cycles.
    let ledger = BudgetLedger::open(None).unwrap();
    ledger.create_tenant("golden", 1000.0, delta).unwrap();
    let cfg = TrainConfig {
        backend: "mock".into(),
        ..TrainConfig::default()
    };
    ledger.reserve("golden", 1, &cfg).unwrap();
    ledger.debit("golden", 1, &h1);
    ledger.reserve("golden", 2, &cfg).unwrap();
    ledger.debit("golden", 2, &h2);

    let doc = ledger.status("golden").unwrap();
    assert_eq!(doc.open_reservations, 0);
    assert_eq!(doc.debited_jobs, 2);
    assert_eq!(
        doc.spent_epsilon.to_bits(),
        composed.to_bits(),
        "ledger spend {} vs one-accountant composition {}",
        doc.spent_epsilon,
        composed
    );
    assert_eq!(
        doc.remaining_epsilon.to_bits(),
        (1000.0 - composed).max(0.0).to_bits(),
        "remaining must be budget minus the composed spend, same bits"
    );
}

#[test]
fn adaptive_policy_schedule_goldens() {
    // ε of each ε-consuming adaptive policy's heterogeneous schedule,
    // pinned against the same independent Python port (per-epoch
    // (q_t, σ_t) blocks composed by summing per-α RDP curves). The α
    // pins are loose, as in `epsilon_golden_values`.
    let delta = 1e-5;

    // Dynamic DP-SGD, linear: σ ramps 0.6 → 1.2 over 4 epochs of 16
    // steps at q = 1/16 (σ_e = 0.6 + (e/3)·0.6).
    let base = EpochKnobs {
        noise_multiplier: 0.6,
        clip_norm: 1.0,
        sample_rate: 0.0625,
    };
    let policy = AdaptivePolicy::NoiseDecay {
        shape: DecayShape::Linear,
        noise_final: 1.2,
        clip_final: 1.0,
    };
    let sched = adaptive::training_schedule(&policy, &base, 4, 16);
    assert_eq!(sched.len(), 4, "4 distinct sigmas, 4 blocks");
    let (eps, alpha) = RdpAccountant::predict_schedule(&sched, delta);
    assert_rel(eps, 9.252442252463918, "noise_decay linear eps");
    assert!((alpha - 2.5).abs() < 0.5, "best alpha {alpha}, expected near 2.5");

    // Dynamic DP-SGD, exponential: σ ramps 0.5 → 2.0 geometrically over
    // 3 epochs of 10 steps at q = 0.05 (σ_e = 0.5·4^(e/2)).
    let base = EpochKnobs {
        noise_multiplier: 0.5,
        clip_norm: 1.0,
        sample_rate: 0.05,
    };
    let policy = AdaptivePolicy::NoiseDecay {
        shape: DecayShape::Exp,
        noise_final: 2.0,
        clip_final: 1.0,
    };
    let sched = adaptive::training_schedule(&policy, &base, 3, 10);
    assert_eq!(sched.len(), 3);
    let (eps, alpha) = RdpAccountant::predict_schedule(&sched, delta);
    assert_rel(eps, 10.456251949781658, "noise_decay exp eps");
    assert!((alpha - 2.3).abs() < 0.5, "best alpha {alpha}, expected near 2.3");

    // DPIS-style rate schedule: q ramps 1/16 → 1/32 linearly over 4
    // epochs of 16 steps at σ = 1 (q_e = 0.0625 − (e/3)·0.03125).
    let base = EpochKnobs {
        noise_multiplier: 1.0,
        clip_norm: 1.0,
        sample_rate: 0.0625,
    };
    let policy = AdaptivePolicy::RateSchedule { rate_final: 0.03125 };
    let sched = adaptive::training_schedule(&policy, &base, 4, 16);
    assert_eq!(sched.len(), 4);
    let (eps, alpha) = RdpAccountant::predict_schedule(&sched, delta);
    assert_rel(eps, 3.404901768845483, "rate_schedule eps");
    assert!((alpha - 4.9).abs() < 0.5, "best alpha {alpha}, expected near 4.9");

    // LayerLr is pure post-processing: its training schedule is the
    // static one, record for record, bit for bit.
    let s_static = adaptive::training_schedule(&AdaptivePolicy::Static, &base, 4, 16);
    let s_lr =
        adaptive::training_schedule(&AdaptivePolicy::LayerLr { strength: 0.5 }, &base, 4, 16);
    assert_eq!(s_static.len(), s_lr.len());
    for (a, b) in s_static.iter().zip(&s_lr) {
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.sample_rate.to_bits(), b.sample_rate.to_bits());
        assert_eq!(a.noise_multiplier.to_bits(), b.noise_multiplier.to_bits());
    }
}

fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n {
        let c = rng.next_below(classes as u64) as i32;
        for f in 0..feats {
            xs.push(0.5 * rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 });
        }
        ys.push(c);
    }
    Dataset {
        xs,
        ys,
        example_numel: feats,
        n_classes: classes,
    }
}

#[test]
fn predicted_schedule_matches_live_adaptive_run_bitwise() {
    // Issue 9 acceptance: `predict_schedule` on a heterogeneous
    // (σ_t, q_t) schedule must match the live run's composed ε down to
    // the last bit. Scheduler `static_random` keeps Analysis blocks out
    // of the history so the comparison covers exactly the training-side
    // composition; the train split has exactly `dataset_size` examples
    // so the live q = B/|D| division is the same division
    // `TrainConfig::sample_rate` performs.
    let cfg = TrainConfig {
        epochs: 4,
        batch_size: 16,
        dataset_size: 256,
        noise_multiplier: 0.6,
        clip_norm: 1.0,
        lr: 0.8,
        quant_fraction: 0.5,
        scheduler: "static_random".into(),
        policy: "noise_decay".into(),
        noise_final: 1.2,
        seed: 3,
        physical_batch: 32,
        ..TrainConfig::default()
    };
    let exec = MockExecutor::new(8, 4, 6, 32);
    let tr = toy_dataset(256, 8, 4, cfg.seed);
    let va = toy_dataset(64, 8, 4, cfg.seed + 1);

    let mut session = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
    session.run(&exec, &tr, &va, &mut NullSink).unwrap();
    let (record, _weights, mut acc) = session.finish();
    let delta = cfg.delta;
    let live = acc.epsilon(delta);

    let policy = AdaptivePolicy::from_config(&cfg).unwrap();
    let base = EpochKnobs {
        noise_multiplier: cfg.noise_multiplier,
        clip_norm: cfg.clip_norm,
        sample_rate: cfg.sample_rate(),
    };
    let steps_per_epoch = (cfg.dataset_size / cfg.batch_size) as u64;
    let sched = adaptive::training_schedule(&policy, &base, cfg.epochs, steps_per_epoch);
    let predicted = RdpAccountant::predict_schedule(&sched, delta);

    assert_eq!(
        predicted.0.to_bits(),
        live.0.to_bits(),
        "predicted ε {} vs live ε {}",
        predicted.0,
        live.0
    );
    assert_eq!(predicted.1, live.1, "best α must agree too");
    assert_eq!(record.final_epsilon.to_bits(), live.0.to_bits());

    // The live history IS the predicted schedule, block for block.
    let history = acc.history();
    assert_eq!(history.len(), sched.len());
    for (h, s) in history.iter().zip(&sched) {
        assert_eq!(h.steps, s.steps);
        assert_eq!(h.sample_rate.to_bits(), s.sample_rate.to_bits());
        assert_eq!(h.noise_multiplier.to_bits(), s.noise_multiplier.to_bits());
    }
}

#[test]
fn zero_rate_analysis_step_costs_nothing() {
    // An empty probe draw accounts `step_analysis(0.0, σ)`: an SGM that
    // touches nobody's data. The accountant must record nothing and
    // report exactly ε = 0 — not a tiny positive number.
    let mut acc = RdpAccountant::new();
    acc.step_analysis(0.0, 0.5);
    assert!(acc.history().is_empty(), "zero-rate steps must not be recorded");
    assert_eq!(acc.steps_of(Mechanism::Analysis), 0);
    let (eps, _) = acc.epsilon(1e-5);
    assert_eq!(eps, 0.0);
    let (eps, _) = acc.epsilon_of(Mechanism::Analysis, 1e-5);
    assert_eq!(eps, 0.0);
}

#[test]
fn accountant_matches_direct_curve_composition() {
    // The accountant's coalesced history must reproduce the direct
    // per-grid-point sum exactly — no drift from caching or coalescing.
    let (q, sigma, steps, delta) = (0.02, 1.0, 1000, 1e-5);
    let direct = epsilon_of_schedule(q, sigma, steps, delta);
    let mut acc = RdpAccountant::new();
    for _ in 0..steps {
        acc.step_training(q, sigma, 1);
    }
    let via_acc = acc.epsilon(delta);
    assert_eq!(via_acc.0.to_bits(), direct.0.to_bits(), "{via_acc:?} vs {direct:?}");
    assert_eq!(via_acc.1, direct.1);
}
