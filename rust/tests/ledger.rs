//! Tier-1 ledger tests: the acceptance contract of the per-tenant
//! privacy-budget ledger (`rust/src/serve/ledger.rs`) over the full
//! HTTP stack.
//!
//! (a) **Exhaustion**: a submit that doesn't fit gets a 403 whose
//!     `remaining_epsilon` is bit-for-bit the number
//!     `GET /v1/tenants/{id}` reports — one ε computation, one wire
//!     encoding, no drift.
//! (b) **Refund**: cancelling a queued tenant job restores the
//!     remaining budget to the exact pre-submit bits.
//! (c) **Crash recovery**: a fabricated kill -9 state (ledger manifest
//!     + a queued tenant job) restarts with the reservation rebuilt
//!     bit-identically, the recovered job debits exactly once, and the
//!     remaining ε is bit-stable across a second restart.
//! (d) **No oversubscription**: three tenants hammered by concurrent
//!     submits each admit exactly the jobs their budget fits — never
//!     one more, no matter the interleaving.
//! (e) **Spend timeline**: `GET /v1/tenants/{id}` carries the ordered
//!     reserve/refund/debit event log with exact post-event bits, and
//!     the log is byte-identical across a daemon restart.
//!
//! Everything runs on `127.0.0.1:0`, in-process, no artifacts —
//! tier-1 like `tests/serve.rs`.

use std::sync::Mutex;
use std::time::Duration;

use dpquant::config::TrainConfig;
use dpquant::privacy::{Mechanism, RdpAccountant};
use dpquant::serve::client::Client;
use dpquant::serve::http::http_call;
use dpquant::serve::jobs::config_to_json;
use dpquant::serve::ledger::{schedule_cost, BudgetLedger};
use dpquant::serve::Daemon;
use dpquant::util::json::{self, Json};

const WAIT: Duration = Duration::from_secs(120);
const POLL: Duration = Duration::from_millis(20);

fn mock_cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        backend: "mock".into(),
        dataset_size: 96,
        val_size: 32,
        batch_size: 16,
        physical_batch: 32,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

fn temp_state_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("dpquant_ledger_{tag}_{}", std::process::id()));
    let dir = dir.to_str().unwrap().to_string();
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A budget that fits exactly `k` copies of `cfg`'s worst-case
/// schedule, composed the ledger's way (one accountant, records in
/// sequence — NOT k × ε of one job, which would be loose).
fn budget_for_jobs(cfg: &TrainConfig, k: usize) -> f64 {
    let cost = schedule_cost(cfg);
    let mut acc = RdpAccountant::new();
    for _ in 0..k {
        acc.record(
            Mechanism::Training,
            cost.sample_rate,
            cost.noise_multiplier,
            cost.train_steps,
        );
        acc.record(
            Mechanism::Analysis,
            cost.analysis_rate,
            cost.analysis_sigma,
            cost.analysis_steps,
        );
    }
    acc.epsilon(cfg.delta).0
}

fn submit_raw(addr: &str, cfg: &TrainConfig, tenant: &str) -> (u16, Json) {
    let body = json::obj(vec![
        ("config", config_to_json(cfg)),
        ("tenant", json::s(tenant)),
    ]);
    http_call(addr, "POST", "/v1/jobs", Some(&body)).unwrap()
}

fn remaining_bits(status: &Json) -> u64 {
    status
        .get("remaining_epsilon")
        .unwrap()
        .as_f64()
        .unwrap()
        .to_bits()
}

// ---------------------------------------------------------------------
// (a) 403 remaining_epsilon == tenant status, bit for bit
// ---------------------------------------------------------------------

#[test]
fn exhausted_submit_403_matches_tenant_status_bits_over_the_wire() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let addr = daemon.addr();
    let client = Client::new(&addr);

    let cfg = mock_cfg(0, 1);
    // Fits exactly one job.
    client.create_tenant("one-shot", budget_for_jobs(&cfg, 1), cfg.delta).unwrap();

    // Job 1 fits (composed estimate == budget, not greater).
    let (status, resp) = submit_raw(&addr, &cfg, "one-shot");
    assert_eq!(status, 201, "{resp}");
    let id = resp.get("id").unwrap().as_usize().unwrap() as u64;
    client.wait(id, WAIT, POLL).unwrap();

    // Job 2 cannot: 403 with the structured refusal.
    let (status, refusal) = submit_raw(&addr, &mock_cfg(1, 1), "one-shot");
    assert_eq!(status, 403, "{refusal}");
    assert_eq!(refusal.get("error").unwrap().as_str(), Some("budget_exhausted"));
    assert_eq!(refusal.get("tenant").unwrap().as_str(), Some("one-shot"));
    assert!(refusal.get("estimated_epsilon").unwrap().as_f64().unwrap() > 0.0);

    // The refusal's remaining ε IS the status document's, bitwise.
    let doc = client.tenant_status("one-shot").unwrap();
    assert_eq!(
        remaining_bits(&refusal),
        remaining_bits(&doc),
        "403 body and GET /v1/tenants/one-shot must agree bit-for-bit: {refusal} vs {doc}"
    );
    assert_eq!(doc.get("debited_jobs").unwrap().as_usize(), Some(1));
    assert_eq!(doc.get("open_reservations").unwrap().as_usize(), Some(0));
    daemon.stop();
}

// ---------------------------------------------------------------------
// (b) cancel refunds the reservation to the exact pre-submit bits
// ---------------------------------------------------------------------

#[test]
fn cancelling_a_queued_tenant_job_refunds_bit_exact() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let addr = daemon.addr();
    let client = Client::new(&addr);

    // Occupy the lone worker so the tenant job stays queued with an
    // open reservation.
    let long = client.submit(&mock_cfg(0, 100_000)).unwrap();

    client.create_tenant("acme", 50.0, 1e-5).unwrap();
    let before = client.tenant_status("acme").unwrap();
    assert_eq!(before.get("remaining_epsilon").unwrap().as_f64(), Some(50.0));

    let (status, resp) = submit_raw(&addr, &mock_cfg(1, 2), "acme");
    assert_eq!(status, 201, "{resp}");
    let id = resp.get("id").unwrap().as_usize().unwrap() as u64;

    let held = client.tenant_status("acme").unwrap();
    assert_eq!(held.get("open_reservations").unwrap().as_usize(), Some(1));
    assert!(
        held.get("remaining_epsilon").unwrap().as_f64().unwrap() < 50.0,
        "an open reservation must reduce the remaining budget: {held}"
    );

    client.cancel(id).unwrap();
    let status = client.wait(id, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("cancelled"));

    let after = client.tenant_status("acme").unwrap();
    assert_eq!(
        remaining_bits(&after),
        remaining_bits(&before),
        "a full refund must restore the exact bits: {after}"
    );
    assert_eq!(after.get("open_reservations").unwrap().as_usize(), Some(0));
    assert_eq!(after.get("debited_jobs").unwrap().as_usize(), Some(0));

    client.cancel(long).unwrap();
    client.wait(long, WAIT, POLL).unwrap();
    daemon.stop();
}

// ---------------------------------------------------------------------
// (c) kill -9: reservation rebuilt bit-identically, debit exactly once
// ---------------------------------------------------------------------

#[test]
fn restart_rebuilds_reservations_and_debits_exactly_once() {
    let dir = temp_state_dir("recover");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = mock_cfg(3, 2);
    let budget = budget_for_jobs(&cfg, 2);

    // Fabricate the kill -9 disk state: the ledger manifest written by
    // a real ledger (create_tenant persists), plus two job manifests a
    // crashed daemon leaves behind — an anonymous long job (id 1) and a
    // queued tenant job (id 2) whose reservation lived only in memory.
    {
        let ledger = BudgetLedger::open(Some(&dir)).unwrap();
        ledger.create_tenant("acme", budget, cfg.delta).unwrap();
    }
    let long_manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(1.0)),
        ("status", json::s("queued")),
        ("epochs_completed", json::num(0.0)),
        ("config", config_to_json(&mock_cfg(0, 100_000))),
        ("error", Json::Null),
        ("summary", Json::Null),
    ]);
    std::fs::write(format!("{dir}/job-1.json"), long_manifest.to_string()).unwrap();
    let tenant_manifest = json::obj(vec![
        ("format", json::s("dpquant-serve-job")),
        ("version", json::num(1.0)),
        ("id", json::num(2.0)),
        ("status", json::s("queued")),
        ("tenant", json::s("acme")),
        ("epochs_completed", json::num(0.0)),
        ("config", config_to_json(&cfg)),
        ("error", Json::Null),
        ("summary", Json::Null),
    ]);
    std::fs::write(format!("{dir}/job-2.json"), tenant_manifest.to_string()).unwrap();

    // What the rebuilt reservation must look like: an independent
    // ledger with the same tenant and the same open reservation.
    let expected_held = {
        let oracle_dir = temp_state_dir("oracle");
        std::fs::create_dir_all(&oracle_dir).unwrap();
        let oracle = BudgetLedger::open(Some(&oracle_dir)).unwrap();
        oracle.create_tenant("acme", budget, cfg.delta).unwrap();
        oracle.reserve("acme", 2, &cfg).unwrap();
        let doc = oracle.status("acme").unwrap();
        std::fs::remove_dir_all(&oracle_dir).ok();
        doc.remaining_epsilon.to_bits()
    };

    // "Restart" with one worker: recovery dispatches the anonymous
    // bucket first, so the long job pins the worker and the tenant
    // job's rebuilt reservation is observable while it queues.
    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());
    let held = client.tenant_status("acme").unwrap();
    assert_eq!(held.get("open_reservations").unwrap().as_usize(), Some(1), "{held}");
    assert_eq!(
        remaining_bits(&held),
        expected_held,
        "recovery must rebuild the reservation bit-identically: {held}"
    );

    // Unblock the worker; the recovered tenant job runs and debits.
    client.cancel(1).unwrap();
    client.wait(1, WAIT, POLL).unwrap();
    let status = client.wait(2, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");

    let done = client.tenant_status("acme").unwrap();
    assert_eq!(done.get("debited_jobs").unwrap().as_usize(), Some(1));
    assert_eq!(done.get("open_reservations").unwrap().as_usize(), Some(0));
    let spent = done.get("spent_epsilon").unwrap().as_f64().unwrap();
    assert!(spent > 0.0 && spent <= budget, "{done}");
    let remaining_before_restart = remaining_bits(&done);
    daemon.stop();

    // Second restart over the settled state: the debit must not happen
    // again and the remaining ε must be bit-stable.
    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());
    let again = client.tenant_status("acme").unwrap();
    assert_eq!(again.get("debited_jobs").unwrap().as_usize(), Some(1), "{again}");
    assert_eq!(again.get("open_reservations").unwrap().as_usize(), Some(0));
    assert_eq!(
        remaining_bits(&again),
        remaining_before_restart,
        "remaining ε must be bit-identical across a restart: {again}"
    );
    daemon.stop();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// (e) the spend timeline: ordered events, exact bits, restart-stable
// ---------------------------------------------------------------------

#[test]
fn tenant_timeline_records_every_event_and_survives_restart_byte_exact() {
    let dir = temp_state_dir("timeline");
    std::fs::create_dir_all(&dir).unwrap();
    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let addr = daemon.addr();
    let client = Client::new(&addr);

    // Pin the worker so both tenant jobs queue with open reservations.
    let long = client.submit(&mock_cfg(0, 100_000)).unwrap();
    let cfg = mock_cfg(1, 1);
    client.create_tenant("acme", budget_for_jobs(&cfg, 2), cfg.delta).unwrap();

    let (s, resp_a) = submit_raw(&addr, &cfg, "acme");
    assert_eq!(s, 201, "{resp_a}");
    let job_a = resp_a.get("id").unwrap().as_usize().unwrap() as u64;
    let (s, resp_b) = submit_raw(&addr, &mock_cfg(2, 1), "acme");
    assert_eq!(s, 201, "{resp_b}");
    let job_b = resp_b.get("id").unwrap().as_usize().unwrap() as u64;

    // Refund B while it is still queued, then let A run to its debit.
    client.cancel(job_b).unwrap();
    client.wait(job_b, WAIT, POLL).unwrap();
    client.cancel(long).unwrap();
    client.wait(long, WAIT, POLL).unwrap();
    let status = client.wait(job_a, WAIT, POLL).unwrap();
    assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");

    let doc = client.tenant_status("acme").unwrap();
    let timeline = doc.get("timeline").unwrap().as_arr().unwrap().to_vec();
    let kinds: Vec<&str> = timeline
        .iter()
        .map(|e| e.get("kind").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(kinds, ["reserve", "reserve", "refund", "debit"], "{doc}");
    let jobs: Vec<u64> = timeline
        .iter()
        .map(|e| e.get("job").unwrap().as_usize().unwrap() as u64)
        .collect();
    assert_eq!(jobs, [job_a, job_b, job_b, job_a], "{doc}");

    let remaining_after = |i: usize| -> u64 {
        timeline[i].get("remaining").unwrap().as_f64().unwrap().to_bits()
    };
    // The refund lands the tenant back on the exact bits it held after
    // the first reservation alone...
    assert_eq!(remaining_after(2), remaining_after(0), "{doc}");
    // ...and the last event's post-state IS the status document's.
    assert_eq!(remaining_after(3), remaining_bits(&doc), "{doc}");
    // The debit event's ε is the tenant's whole recorded spend (one
    // debited job), bit for bit.
    assert_eq!(
        timeline[3].get("epsilon").unwrap().as_f64().unwrap().to_bits(),
        doc.get("spent_epsilon").unwrap().as_f64().unwrap().to_bits(),
        "{doc}"
    );
    let wire_before = doc.get("timeline").unwrap().to_string();
    daemon.stop();

    // kill -9 equivalence: a fresh daemon over the same state dir must
    // serve the identical timeline, byte for byte.
    let daemon = Daemon::start("127.0.0.1:0", 1, Some(&dir)).unwrap();
    let client = Client::new(&daemon.addr());
    let doc = client.tenant_status("acme").unwrap();
    assert_eq!(
        doc.get("timeline").unwrap().to_string(),
        wire_before,
        "the spend timeline must be byte-identical across a restart: {doc}"
    );
    daemon.stop();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// (d) concurrent submits never oversubscribe a budget
// ---------------------------------------------------------------------

#[test]
fn three_tenants_of_concurrent_submits_never_oversubscribe() {
    let daemon = Daemon::start("127.0.0.1:0", 1, None).unwrap();
    let addr = daemon.addr();
    let client = Client::new(&addr);

    // Pin the lone worker so no debit lands during the submit storm —
    // every admission decision is reservations-vs-budget, atomically
    // under the ledger lock.
    let long = client.submit(&mock_cfg(0, 100_000)).unwrap();

    let cfg = mock_cfg(1, 1);
    let fits = 2usize;
    let budget = budget_for_jobs(&cfg, fits);
    let tenants = ["t-red", "t-green", "t-blue"];
    for t in &tenants {
        client.create_tenant(t, budget, cfg.delta).unwrap();
    }

    // 6 submits per tenant from 6 threads, interleaved.
    let accepted = Mutex::new(Vec::<(String, u64)>::new());
    let rejected = Mutex::new(Vec::<String>::new());
    std::thread::scope(|scope| {
        for round in 0..2 {
            for chunk in 0..3 {
                let accepted = &accepted;
                let rejected = &rejected;
                let addr = &addr;
                let tenants = &tenants;
                scope.spawn(move || {
                    for (i, t) in tenants.iter().enumerate() {
                        let seed = (round * 100 + chunk * 10 + i) as u64;
                        let (status, resp) = submit_raw(addr, &mock_cfg(seed, 1), t);
                        match status {
                            201 => accepted.lock().unwrap().push((
                                t.to_string(),
                                resp.get("id").unwrap().as_usize().unwrap() as u64,
                            )),
                            403 => {
                                assert_eq!(
                                    resp.get("error").unwrap().as_str(),
                                    Some("budget_exhausted"),
                                    "{resp}"
                                );
                                rejected.lock().unwrap().push(t.to_string());
                            }
                            other => panic!("unexpected submit status {other}: {resp}"),
                        }
                    }
                });
            }
        }
    });
    let accepted = accepted.into_inner().unwrap();
    let rejected = rejected.into_inner().unwrap();

    // Each tenant admitted exactly what its budget fits — regardless of
    // thread interleaving — and refused the rest.
    for t in &tenants {
        let a = accepted.iter().filter(|(name, _)| name == t).count();
        let r = rejected.iter().filter(|name| name == *t).count();
        assert_eq!(a, fits, "tenant {t}: accepted {a} of budget-for-{fits}");
        assert_eq!(r, 6 - fits, "tenant {t}: rejected {r}");
        let doc = client.tenant_status(t).unwrap();
        assert_eq!(doc.get("open_reservations").unwrap().as_usize(), Some(fits));
        assert!(
            doc.get("remaining_epsilon").unwrap().as_f64().unwrap() >= 0.0,
            "{doc}"
        );
    }

    // Drain: the accepted jobs run; debits never exceed the budget.
    client.cancel(long).unwrap();
    client.wait(long, WAIT, POLL).unwrap();
    for (_, id) in &accepted {
        let status = client.wait(*id, WAIT, POLL).unwrap();
        assert_eq!(status.get("status").unwrap().as_str(), Some("done"), "{status}");
    }
    for t in &tenants {
        let doc = client.tenant_status(t).unwrap();
        assert_eq!(doc.get("debited_jobs").unwrap().as_usize(), Some(fits), "{doc}");
        assert_eq!(doc.get("open_reservations").unwrap().as_usize(), Some(0));
        let spent = doc.get("spent_epsilon").unwrap().as_f64().unwrap();
        assert!(
            spent > 0.0 && spent <= budget,
            "tenant {t} oversubscribed: spent {spent} of {budget}"
        );
    }
    daemon.stop();
}
