//! Quickstart: the end-to-end driver — **no artifacts required**.
//!
//! Builds the native pure-Rust execution backend (real forward/backward
//! passes, exact per-sample gradient clipping, LUQ-FP4 kernels on the
//! live compute path), generates a synthetic GTSRB-like dataset, and
//! drives a [`TrainSession`] — the resumable training state machine —
//! epoch by epoch with the full DPQuant scheduler (Algorithm 1
//! loss-impact analysis + Algorithm 2 probabilistic layer selection)
//! under a fixed privacy budget.
//!
//! Along the way it demonstrates the session API's three pillars:
//! * **observability** — a custom [`EventSink`] logs analyses and
//!   epochs from the typed event stream (no flags, no println taps);
//! * **checkpointing** — the run snapshots itself at the halfway mark
//!   and proves `resume` continues bit-exactly;
//! * **stepping** — `step_epoch()` hands control back every epoch, the
//!   hook later PRs use for job multiplexing and early stopping.
//!
//!     cargo run --release --example quickstart
//!
//! To target the AOT-compiled PJRT graphs instead, run the `dpquant`
//! CLI with `--backend pjrt` after `make artifacts`. The run is
//! recorded in EXPERIMENTS.md §End-to-end.

use dpquant::backend::NativeExecutor;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{EpochOutcome, EventSink, TrainEvent, TrainSession};
use dpquant::data;
use dpquant::util::error::Result;

/// A sink that narrates the run from the typed event stream.
struct Narrator;

impl EventSink for Narrator {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        match event {
            TrainEvent::AnalysisCompleted { epoch, impacts, .. } => {
                let worst = impacts
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(l, _)| l)
                    .unwrap_or(0);
                println!("  [epoch {epoch}] loss-impact analysis: layer {worst} most sensitive");
            }
            TrainEvent::EpochCompleted { record } => {
                println!(
                    "  epoch {:>2}  loss {:.4}  val_acc {:.3}  eps {:.3}  layers {:?}",
                    record.epoch,
                    record.train_loss,
                    record.val_accuracy,
                    record.epsilon,
                    record.quantized_layers
                );
            }
            TrainEvent::Truncated { epoch, epsilon, .. } => {
                println!("  [epoch {epoch}] privacy budget reached (eps {epsilon:.3}); stopping");
            }
            _ => {}
        }
    }
}

fn main() -> Result<()> {
    let cfg = TrainConfig {
        model: "miniconvnet".into(),
        dataset: "gtsrb".into(),
        quantizer: "luq4".into(),
        scheduler: "dpquant".into(),
        epochs: 10,
        dataset_size: 2048,
        val_size: 512,
        batch_size: 64,
        noise_multiplier: 1.0,
        clip_norm: 1.0,
        lr: 0.5,
        quant_fraction: 0.75,
        target_epsilon: Some(8.0),
        ..TrainConfig::default()
    };

    println!("== DPQuant quickstart (native backend, zero artifacts) ==");
    println!(
        "model={} dataset={} quantizer={} scheduler={} quant_fraction={}",
        cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler, cfg.quant_fraction
    );

    let full = data::generate(&cfg.dataset, cfg.dataset_size + cfg.val_size, cfg.seed)?;
    let (train_ds, val_ds) = full.split(cfg.val_size);
    let exec = NativeExecutor::from_config(&cfg, train_ds.example_numel, train_ds.n_classes)?;

    // The session owns all cross-epoch state; we own the loop.
    let mut session = TrainSession::builder(cfg.clone()).build(&exec, &train_ds)?;
    let mut narrator = Narrator;
    let ckpt_path = std::env::temp_dir().join("dpquant_quickstart_ckpt.json");
    let ckpt_path = ckpt_path.to_string_lossy().to_string();
    let mut ckpt_written = false;
    loop {
        match session.step_epoch(&exec, &train_ds, &val_ds, &mut narrator)? {
            EpochOutcome::Finished => break,
            _ => {
                if !ckpt_written && session.epochs_completed() >= cfg.epochs / 2 {
                    session.checkpoint(&ckpt_path)?;
                    ckpt_written = true;
                    println!("  [checkpoint] full session state -> {ckpt_path}");
                }
            }
        }
    }
    let (record, _weights, _accountant) = session.finish();

    // Prove the checkpoint restores bit-exactly: resume from the
    // mid-run snapshot and finish the run a second time.
    if ckpt_written {
        println!("\nresuming from the mid-run checkpoint (should match bit-for-bit):");
        let mut resumed = TrainSession::resume(&ckpt_path, &exec)?;
        let mut quiet = dpquant::coordinator::NullSink;
        resumed.run(&exec, &train_ds, &val_ds, &mut quiet)?;
        let (rec2, _, _) = resumed.finish();
        assert_eq!(
            rec2.final_accuracy.to_bits(),
            record.final_accuracy.to_bits(),
            "resume must reproduce the uninterrupted run exactly"
        );
        assert_eq!(rec2.final_epsilon.to_bits(), record.final_epsilon.to_bits());
        println!(
            "  resumed run: val_acc={:.4} eps={:.3} — identical to the uninterrupted run",
            rec2.final_accuracy, rec2.final_epsilon
        );
        std::fs::remove_file(&ckpt_path).ok();
    }

    println!("\nloss curve:");
    for e in &record.epochs {
        let bar = "#".repeat((e.train_loss * 12.0).min(60.0) as usize);
        println!("  epoch {:>2}  {:.4} {}", e.epoch, e.train_loss, bar);
    }
    println!(
        "\nfinal: val_acc={:.4} (best {:.4})  eps={:.3} of target {:?}  analysis-eps={:.3}",
        record.final_accuracy,
        record.best_accuracy,
        record.final_epsilon,
        cfg.target_epsilon,
        record.analysis_epsilon,
    );
    let path = record.save("results")?;
    println!("run record: {path}");
    Ok(())
}
