//! Quickstart: the end-to-end driver — **no artifacts required**.
//!
//! Builds the native pure-Rust execution backend (real forward/backward
//! passes, exact per-sample gradient clipping, LUQ-FP4 kernels on the
//! live compute path), generates a synthetic GTSRB-like dataset, and
//! trains the mini CNN with the full DPQuant scheduler (Algorithm 1
//! loss-impact analysis + Algorithm 2 probabilistic layer selection)
//! under a fixed privacy budget, logging the loss curve and ε per epoch.
//!
//!     cargo run --release --example quickstart
//!
//! To target the AOT-compiled PJRT graphs instead, run the `dpquant`
//! CLI with `--backend pjrt` after `make artifacts`. The run is
//! recorded in EXPERIMENTS.md §End-to-end.

use dpquant::backend::NativeExecutor;
use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, TrainerOptions};
use dpquant::data;
use dpquant::util::error::{Error, Result};

fn main() -> Result<()> {
    let cfg = TrainConfig {
        model: "miniconvnet".into(),
        dataset: "gtsrb".into(),
        quantizer: "luq4".into(),
        scheduler: "dpquant".into(),
        epochs: 10,
        dataset_size: 2048,
        val_size: 512,
        batch_size: 64,
        noise_multiplier: 1.0,
        clip_norm: 1.0,
        lr: 0.5,
        quant_fraction: 0.75,
        target_epsilon: Some(8.0),
        ..TrainConfig::default()
    };

    println!("== DPQuant quickstart (native backend, zero artifacts) ==");
    println!(
        "model={} dataset={} quantizer={} scheduler={} quant_fraction={}",
        cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler, cfg.quant_fraction
    );

    let full = data::generate(&cfg.dataset, cfg.dataset_size + cfg.val_size, cfg.seed)
        .map_err(Error::msg)?;
    let (train_ds, val_ds) = full.split(cfg.val_size);
    let exec = NativeExecutor::from_config(&cfg, train_ds.example_numel, train_ds.n_classes)?;

    let opts = TrainerOptions {
        collect_step_stats: false,
        verbose: true,
    };
    let res = train(&exec, &cfg, &train_ds, &val_ds, &opts)?;

    println!("\nloss curve:");
    for e in &res.record.epochs {
        let bar = "#".repeat((e.train_loss * 12.0).min(60.0) as usize);
        println!("  epoch {:>2}  {:.4} {}", e.epoch, e.train_loss, bar);
    }
    println!(
        "\nfinal: val_acc={:.4} (best {:.4})  eps={:.3} of target {:?}  analysis-eps={:.3}",
        res.record.final_accuracy,
        res.record.best_accuracy,
        res.record.final_epsilon,
        cfg.target_epsilon,
        res.record.analysis_epsilon,
    );
    let path = res.record.save("results")?;
    println!("run record: {path}");
    Ok(())
}
