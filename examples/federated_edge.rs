//! Federated-edge scenario (the intro's motivation): resource-
//! constrained devices must quantize aggressively (90% of layers) to
//! meet a compute budget. Compare the naive static schedule an edge
//! runtime would pick against DPQuant's dynamic schedule at the same
//! budget, and report the modeled on-device speedup.
//!
//!     cargo run --release --example federated_edge

use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, TrainerOptions};
use dpquant::data;
use dpquant::perfmodel::SpeedupModel;
use dpquant::runtime::Runtime;
use dpquant::util::error::Result;

fn main() -> Result<()> {
    let cfg_base = TrainConfig {
        model: "miniconvnet".into(),
        dataset: "emnist".into(),
        quantizer: "luq4".into(),
        epochs: 8,
        dataset_size: 1536,
        val_size: 384,
        batch_size: 64,
        noise_multiplier: 1.0,
        quant_fraction: 0.9, // the edge compute budget
        target_epsilon: Some(8.0),
        ..TrainConfig::default()
    };

    let rt = Runtime::open("artifacts")?;
    let graph = rt.load("miniconvnet_emnist_luq4")?;
    let full = data::generate("emnist", cfg_base.dataset_size + cfg_base.val_size, 3)?;
    let (train_ds, val_ds) = full.split(cfg_base.val_size);

    println!("== Federated edge: 90% of layers must run in FP4 ==");
    let mut results = Vec::new();
    for scheduler in ["static_random", "pls", "dpquant"] {
        let mut cfg = cfg_base.clone();
        cfg.scheduler = scheduler.into();
        let res = train(&graph, &cfg, &train_ds, &val_ds, &TrainerOptions::default())?;
        println!(
            "{scheduler:>14}: best_acc={:.4} eps={:.3}",
            res.record.best_accuracy, res.record.final_epsilon
        );
        results.push((scheduler, res.record.best_accuracy));
    }

    // Modeled device speedup at this budget (fp4-capable edge NPU,
    // conservative 4x ops — paper §6.4).
    let m = SpeedupModel::from_table14(1.0, 0.06, 0.02, 4.0);
    println!(
        "\nmodeled on-device speedup at 90% quantized: {:.2}x over fp16 (paper: 1.75-2.21x)",
        m.speedup(0.9)
    );
    println!(
        "DPQuant recovers accuracy at the same compute budget: {:?}",
        results
    );
    Ok(())
}
