//! Reproduce the paper's Section-4 phenomenon in miniature: DP noise
//! amplifies quantization variance.
//!
//! Three measurements, all without artifacts (pure Rust quantizer
//! mirrors + the mock executor), so this example runs in milliseconds:
//!
//! 1. Prop. 1: Var(q(x)) = Θ(‖x‖∞²) — empirical variance vs scale;
//! 2. Eq. 2: ‖noise‖∞ ≈ ‖ḡ‖₂ ≫ ‖ḡ‖∞ in high dimensions;
//! 3. the downstream effect: quantized DP training degrades more than
//!    quantized non-DP training on the same task.
//!
//!     cargo run --release --example degradation_study

use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, MockExecutor, TrainerOptions};
use dpquant::data::Dataset;
use dpquant::quant::{by_name, empirical_variance};
use dpquant::util::error::Result;
use dpquant::util::gaussian::GaussianSampler;
use dpquant::util::rng::Xoshiro256;

fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n {
        let c = rng.next_below(classes as u64) as i32;
        for f in 0..feats {
            xs.push(0.8 * rng.next_f32() + if f == c as usize { 0.45 } else { 0.0 });
        }
        ys.push(c);
    }
    Dataset {
        xs,
        ys,
        example_numel: feats,
        n_classes: classes,
    }
}

fn main() -> Result<()> {
    println!("== 1. Proposition 1: quantization variance scales with ‖x‖∞² ==");
    let q = by_name("luq4").unwrap();
    let mut g = GaussianSampler::seed_from_u64(1);
    let x1: Vec<f32> = (0..256).map(|_| g.standard() as f32).collect();
    for lambda in [1.0f32, 2.0, 4.0, 8.0] {
        let xs: Vec<f32> = x1.iter().map(|&v| lambda * v).collect();
        let var = empirical_variance(q.as_ref(), &xs, 2000, 7);
        println!("  scale {lambda:>3}: Var(q(x)) = {var:.5}  (expect ∝ {:.0})", lambda * lambda);
    }

    println!("\n== 2. Equation 2: noise ∞-norm vs clipped-gradient norms ==");
    for n in [100usize, 1_000, 10_000, 100_000] {
        // A clipped gradient with ‖g‖₂ = C = 1 spread over n coords, and
        // N(0, C²) noise (σ = 1).
        let per = 1.0 / (n as f64).sqrt();
        let mut gs = GaussianSampler::seed_from_u64(n as u64);
        let mut noise_linf = 0f64;
        for _ in 0..n {
            noise_linf = noise_linf.max(gs.standard().abs());
        }
        println!(
            "  n={n:>6}: ‖ḡ‖∞={per:.4}  ‖ḡ‖₂=1.0  ‖noise‖∞={noise_linf:.2}  gap=2^{:.1}",
            (noise_linf / per).log2()
        );
    }

    println!("\n== 3. Downstream: quantized DP vs quantized non-DP training ==");
    let mut exec = MockExecutor::new(16, 8, 8, 32);
    // Aggressive per-layer quantization damage so the miniature shows the
    // same separation the real FP4 kernels show at scale.
    exec.layer_sensitivity = (0..8).map(|i| 4.0 + i as f32).collect();
    let ds = toy_dataset(1024 + 256, 16, 8, 3);
    let (tr, va) = ds.split(256);
    let mut rows = Vec::new();
    for (label, sigma) in [("non-DP", 1e-4), ("DP (sigma=1)", 1.0)] {
        for (sched, frac) in [("none", 0.0), ("all", 1.0)] {
            let cfg = TrainConfig {
                scheduler: sched.into(),
                quant_fraction: frac,
                noise_multiplier: sigma,
                epochs: 6,
                batch_size: 32,
                dataset_size: 1024,
                lr: 0.6,
                ..TrainConfig::default()
            };
            let res = train(&exec, &cfg, &tr, &va, &TrainerOptions::default())?;
            rows.push((label, sched, res.record.best_accuracy));
        }
    }
    let mut drop = [0f64; 2];
    for (i, (label, _, _)) in rows.iter().enumerate().filter(|(i, _)| i % 2 == 0) {
        let fp = rows[i].2;
        let quant = rows[i + 1].2;
        println!("  {label:>13}: fp={fp:.4}  all-quantized={quant:.4}  drop={:+.4}", quant - fp);
        drop[i / 2] = fp - quant;
    }
    println!(
        "\nDP drop / non-DP drop = {:.1}x  (paper Fig 1a: DP degrades far more)",
        drop[1] / drop[0].max(1e-6)
    );
    Ok(())
}
