//! DP-AdamW on the BERT/SNLI stand-in (paper §A.4.2 + Table 1 last rows):
//! a frozen-embedding TinyTransformer classifies synthetic premise/
//! hypothesis pairs; only the last block + head train, under DP-AdamW,
//! with DPQuant scheduling the 7 quantizable matmuls.
//!
//!     cargo run --release --example dp_adam

use dpquant::config::{OptimizerKind, TrainConfig};
use dpquant::coordinator::{train, TrainerOptions};
use dpquant::data;
use dpquant::runtime::Runtime;
use dpquant::util::error::Result;

fn main() -> Result<()> {
    let mut cfg = TrainConfig {
        model: "tinytransformer".into(),
        dataset: "snli".into(),
        quantizer: "luq4".into(),
        optimizer: OptimizerKind::AdamW,
        lr: 0.01,
        epochs: 8,
        dataset_size: 2048,
        val_size: 512,
        batch_size: 64,
        noise_multiplier: 1.0,
        quant_fraction: 0.75,
        target_epsilon: Some(8.0),
        ..TrainConfig::default()
    };

    let rt = Runtime::open("artifacts")?;
    let graph = rt.load("tinytransformer_snli_luq4")?;
    let full = data::generate("snli", cfg.dataset_size + cfg.val_size, 7)?;
    let (train_ds, val_ds) = full.split(cfg.val_size);

    println!("== DP-AdamW + DPQuant on SNLI-like sequence pairs ==");
    for scheduler in ["static_random", "dpquant"] {
        cfg.scheduler = scheduler.into();
        let res = train(
            &graph,
            &cfg,
            &train_ds,
            &val_ds,
            &TrainerOptions {
                verbose: false,
                ..Default::default()
            },
        )?;
        println!(
            "{scheduler:>14}: best_acc={:.4} final_eps={:.3} (3-way task, chance 0.333)",
            res.record.best_accuracy, res.record.final_epsilon
        );
        if scheduler == "dpquant" {
            // Which layers did the scheduler protect?
            let last = res.record.epochs.last().unwrap();
            let names = &graph.info.quant_layer_names;
            let kept: Vec<&str> = (0..names.len())
                .filter(|i| !last.quantized_layers.contains(i))
                .map(|i| names[i].as_str())
                .collect();
            println!("  layers kept full-precision in the last epoch: {kept:?}");
        }
    }
    Ok(())
}
