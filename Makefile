# DPQuant build entry points. `make verify` mirrors the tier-1 gate
# exactly; everything else is convenience around it.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all verify build test fmt fmt-check clippy bench bench-smoke artifacts clean

all: verify

## Tier-1 verification, exactly as CI and the roadmap run it.
verify:
	$(CARGO) build --release
	$(CARGO) test -q

build:
	$(CARGO) build --release --all-targets

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all

fmt-check:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

## Full bench suite (uses artifacts when present, skips PJRT benches
## loudly otherwise).
bench:
	$(CARGO) bench

## CI smoke: quantizer + native-backend benches, tiny iteration budget.
bench-smoke:
	DPQUANT_BENCH_QUICK=1 $(CARGO) bench -- quantizers
	DPQUANT_BENCH_QUICK=1 $(CARGO) bench -- backend

## AOT-export the JAX/Pallas train+eval graphs into rust/artifacts/
## (the directory rust/tests/integration.rs and the PJRT benches read).
## Skips with an explanation when the Python toolchain is unavailable —
## the pure-Rust suite runs fine without artifacts, and executing the
## compiled graphs additionally needs a real `xla` backend in place of
## the bundled stub (see rust/src/xla.rs).
artifacts:
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
		cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts; \
	else \
		echo "SKIP: $(PYTHON) with jax is not available; rust/artifacts/ not built."; \
		echo "  - cargo test / cargo bench run without artifacts (PJRT paths skip loudly)."; \
		echo "  - To build artifacts: install jax, then re-run 'make artifacts'."; \
		echo "  - To execute them:   vendor a real 'xla' crate (see rust/src/xla.rs)."; \
	fi

clean:
	$(CARGO) clean
	rm -rf results
